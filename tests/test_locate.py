"""Locate->gather edge cases: the branch-free binary search and every
gather kernel must agree with the one-hot scan path bit-for-bit on the
awkward inputs — endpoints exactly on segment/leaf boundaries, endpoints
outside the domain, sentinel-padded tail tiles, and empty or single-entry
delta buffers."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import build_index_1d, build_index_2d  # noqa: E402
from repro.core.poly import locate  # noqa: E402
from repro.engine import (BACKENDS, DynamicEngine,  # noqa: E402
                          DynamicEngine2D, Engine, build_plan, build_plan_2d)
from repro.engine.plan import big_sentinel  # noqa: E402
from repro.kernels.delta_scan import (delta_count2d_gather_pallas,  # noqa: E402
                                      delta_max_gather_pallas,
                                      delta_sum_gather_pallas)
from repro.kernels.locate import (bsearch_count, dyadic_cuts,  # noqa: E402
                                  locate_pallas)
from repro.kernels.ref import (delta_count2d_ref, delta_max_ref,  # noqa: E402
                               delta_sum_ref)

PALLAS_BACKENDS = ("pallas", "pallas_scan")


# ---------------------------------------------------------------------------
# the binary-search primitive
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 3, 17, 256, 1000])
@pytest.mark.parametrize("side", ["left", "right"])
def test_bsearch_count_matches_searchsorted(n, side):
    rng = np.random.default_rng(n)
    keys = np.sort(rng.uniform(0, 100, n))
    q = np.concatenate([rng.uniform(-10, 110, 199), keys[: min(n, 50)],
                        [keys[0], keys[-1], -1e30, 1e30]])
    got = np.asarray(bsearch_count(jnp.asarray(keys), jnp.asarray(q),
                                   side=side))
    np.testing.assert_array_equal(got, np.searchsorted(keys, q, side=side))


def test_bsearch_count_duplicate_keys():
    keys = np.array([1.0, 3.0, 3.0, 3.0, 7.0, 7.0, 9.0])
    q = np.array([3.0, 7.0, 0.0, 9.0, 10.0])
    for side in ("left", "right"):
        got = np.asarray(bsearch_count(jnp.asarray(keys), jnp.asarray(q),
                                       side=side))
        np.testing.assert_array_equal(got, np.searchsorted(keys, q, side=side))


def test_locate_kernel_boundary_and_sentinel_tail():
    """Endpoints exactly on seg_lo boundaries, below/above the domain, and
    a table whose tail is sentinel tiles must all match core.poly.locate."""
    rng = np.random.default_rng(0)
    seg = np.sort(rng.uniform(0, 100, 37))
    big = big_sentinel(np.float64)
    padded = np.concatenate([seg, np.full(512 - 37, big)])   # sentinel tail
    q = np.concatenate([seg,                     # exactly on every boundary
                        seg - 1e-9, seg + 1e-9,  # straddling them
                        [-1e9, seg[0] - 1.0, seg[-1] + 1.0, 1e9],
                        rng.uniform(-5, 105, 141)])
    q = np.pad(q, (0, (-len(q)) % 256), constant_values=seg[0])
    got = np.asarray(locate_pallas(jnp.asarray(q), jnp.asarray(padded),
                                   bq=256))
    want = np.asarray(locate(jnp.asarray(q), jnp.asarray(padded)))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# static engine paths on boundary endpoints
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def boundary_setup():
    rng = np.random.default_rng(5)
    keys = np.sort(rng.uniform(0, 500, 2000))
    meas = rng.uniform(0, 10, 2000)
    return keys, meas


@pytest.mark.parametrize("agg,deg", [("sum", 2), ("count", 2), ("max", 3),
                                     ("min", 3)])
def test_gather_bit_identical_on_boundaries_1d(boundary_setup, agg, deg):
    keys, meas = boundary_setup
    m = None if agg == "count" else (
        meas * 100 if agg in ("max", "min") else meas)
    idx = build_index_1d(keys, m, agg, deg=deg, delta=20.0)
    plan = build_plan(idx)
    sl = np.asarray(idx.seg_lo)
    sh = np.asarray(idx.seg_hi)
    lq = np.concatenate([sl, sh, [-1e9, sl[0], sh[-1]]])
    uq = np.concatenate([sh, sl + (sh - sl) / 2, [sl[-1], 1e9, 1e9]])
    lq, uq = np.minimum(lq, uq), np.maximum(lq, uq)
    outs = {b: np.asarray(Engine(backend=b).query(plan, lq, uq).answer)
            for b in BACKENDS}
    # the gather path reads the very rows the one-hot matmul selects
    np.testing.assert_array_equal(outs["pallas"], outs["pallas_scan"])
    np.testing.assert_array_equal(outs["pallas"], outs["ref"])
    np.testing.assert_allclose(outs["pallas"], outs["xla"], rtol=1e-9,
                               atol=1e-9)


def test_gather_bit_identical_on_split_lines_2d():
    rng = np.random.default_rng(9)
    px = rng.uniform(0, 120, 4000)
    py = rng.uniform(0, 120, 4000)
    idx = build_index_2d(px, py, deg=2, delta=20.0, max_depth=5)
    plan = build_plan_2d(idx)
    assert plan.leaf_z is not None
    xc = np.asarray(plan.xcuts)
    yc = np.asarray(plan.ycuts)
    x0, x1, y0, y1 = plan.root
    # corners exactly on split lines + the root's own corners/edges
    lx = np.concatenate([xc, [x0, x0, x1], rng.uniform(0, 120, 29)])
    ux = np.concatenate([xc + 1.0, [x1, x0, x1], rng.uniform(0, 120, 29)])
    ly = np.concatenate([yc, [y0, y1, y0], rng.uniform(0, 120, 29)])
    uy = np.concatenate([yc + 1.0, [y1, y1, y1], rng.uniform(0, 120, 29)])
    lx, ux = np.minimum(lx, ux), np.maximum(lx, ux)
    ly, uy = np.minimum(ly, uy), np.maximum(ly, uy)
    outs = {b: np.asarray(Engine(backend=b).count2d(plan, lx, ux, ly, uy)
                          .answer) for b in BACKENDS}
    np.testing.assert_array_equal(outs["pallas"], outs["pallas_scan"])
    np.testing.assert_array_equal(outs["pallas"], outs["ref"])
    np.testing.assert_allclose(outs["pallas"], outs["xla"], rtol=1e-9,
                               atol=1e-9)


def test_morton_leaf_table_is_sorted_and_disjoint():
    rng = np.random.default_rng(11)
    idx = build_index_2d(rng.uniform(0, 50, 3000), rng.uniform(0, 50, 3000),
                        deg=2, delta=15.0, max_depth=4)
    plan = build_plan_2d(idx)
    z = np.asarray(plan.leaf_z)[: idx.n_leaves]
    assert np.all(np.diff(z) > 0), "leaf z-interval starts must be sorted"
    assert z[0] == 0, "the first leaf must cover Morton cell 0"
    cuts = dyadic_cuts(*map(float, plan.root[:2]), idx.max_depth)
    assert len(cuts) == (1 << idx.max_depth) - 1


# ---------------------------------------------------------------------------
# delta-buffer kernels: empty and single-entry buffers
# ---------------------------------------------------------------------------

def _padded_buffer(fill, cap=64, seed=0):
    rng = np.random.default_rng(seed)
    big = big_sentinel(np.float64)
    keys = np.full(cap, big)
    vals = np.zeros(cap)
    keys[:fill] = np.sort(rng.uniform(0, 100, fill))
    vals[:fill] = rng.uniform(-5, 5, fill)
    return jnp.asarray(keys), jnp.asarray(vals)


@pytest.mark.parametrize("fill", [0, 1, 2, 64])
def test_delta_sum_gather_matches_ref(fill):
    keys, vals = _padded_buffer(fill)
    cf = jnp.concatenate([jnp.zeros((1,)), jnp.cumsum(vals)])
    rng = np.random.default_rng(fill + 1)
    lq = jnp.asarray(np.sort(rng.uniform(-10, 110, 128)))
    uq = lq + 20.0
    got = np.asarray(delta_sum_gather_pallas(lq, uq, keys, cf, bq=128))
    want = np.asarray(delta_sum_ref(lq, uq, keys, vals))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("fill", [0, 1, 2, 64])
def test_delta_max_gather_matches_ref(fill):
    from repro.engine.dynamic import _sparse_table_jnp
    keys, vals = _padded_buffer(fill, seed=3)
    st = _sparse_table_jnp(vals, cap=64)
    rng = np.random.default_rng(fill + 7)
    lq = jnp.asarray(np.sort(rng.uniform(-10, 110, 128)))
    uq = lq + 15.0
    got = np.asarray(delta_max_gather_pallas(lq, uq, keys, st, bq=128))
    want = np.asarray(delta_max_ref(lq, uq, keys, vals))
    np.testing.assert_array_equal(got, want)    # max is exact


@pytest.mark.parametrize("fill", [0, 1, 2, 64])
def test_delta_count2d_gather_matches_ref(fill):
    from repro.engine.dynamic import _mst_levels_jnp
    rng = np.random.default_rng(fill + 13)
    big = big_sentinel(np.float64)
    xs = np.full(64, big)
    ys = np.full(64, big)
    xs[:fill] = np.sort(rng.uniform(0, 100, fill))
    ys[:fill] = rng.uniform(0, 100, fill)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    ylv = _mst_levels_jnp(ys, cap=64)
    qs = [jnp.asarray(rng.uniform(-10, 110, 128)) for _ in range(2)]
    lx, ly = qs
    ux, uy = lx + 30.0, ly + 30.0
    got = np.asarray(delta_count2d_gather_pallas(lx, ux, ly, uy, xs, ylv,
                                                 bq=128))
    want = np.asarray(delta_count2d_ref(lx, ux, ly, uy, xs, ys))
    np.testing.assert_array_equal(got, want)    # integer counts are exact


# ---------------------------------------------------------------------------
# dynamic engines with empty / single-entry buffers, all backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("agg", ["count", "max"])
def test_dynamic_empty_and_single_entry_buffers(boundary_setup, agg):
    keys, meas = boundary_setup
    m = None if agg == "count" else meas * 100
    idx = build_index_1d(keys, m, agg, deg=2 if agg == "count" else 3,
                         delta=20.0)
    rng = np.random.default_rng(17)
    a = keys[rng.integers(0, len(keys), 64)]
    b = keys[rng.integers(0, len(keys), 64)]
    lq, uq = np.minimum(a, b), np.maximum(a, b)
    ref_empty = ref_single = None
    for backend in BACKENDS:
        dyn = DynamicEngine(idx, backend=backend, capacity=64,
                            auto_refit=False)
        r0 = np.asarray(dyn.query(lq, uq).answer)       # empty buffer
        dyn.insert(np.array([keys[100]]),
                   None if agg == "count" else np.array([123.0]))
        r1 = np.asarray(dyn.query(lq, uq).answer)       # single entry
        if ref_empty is None:
            ref_empty, ref_single = r0, r1
        else:
            np.testing.assert_allclose(r0, ref_empty, rtol=1e-9, atol=1e-9)
            np.testing.assert_allclose(r1, ref_single, rtol=1e-9, atol=1e-9)


def test_dynamic2d_empty_and_single_entry_buffers():
    rng = np.random.default_rng(23)
    px = rng.uniform(0, 80, 2500)
    py = rng.uniform(0, 80, 2500)
    idx = build_index_2d(px, py, deg=2, delta=20.0, max_depth=5)
    qa = rng.uniform(0, 80, 64)
    qb = qa + rng.uniform(0.5, 30, 64)
    qc = rng.uniform(0, 80, 64)
    qd = qc + rng.uniform(0.5, 30, 64)
    ref_empty = ref_single = None
    for backend in BACKENDS:
        dyn = DynamicEngine2D(idx, backend=backend, capacity=64,
                              auto_refit=False)
        r0 = np.asarray(dyn.count2d(qa, qb, qc, qd).answer)
        dyn.insert(np.array([40.0]), np.array([40.0]))
        r1 = np.asarray(dyn.count2d(qa, qb, qc, qd).answer)
        if ref_empty is None:
            ref_empty, ref_single = r0, r1
        else:
            np.testing.assert_array_equal(r0, ref_empty)   # integer counts
            np.testing.assert_array_equal(r1, ref_single)
