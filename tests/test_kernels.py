"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import build_index_1d, query_max, query_sum  # noqa: E402
from repro.kernels import from_index, poly_eval, range_max, range_sum  # noqa: E402


def _index(agg, deg, n=8000, seed=0, h_target=None):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.uniform(0, 1000, n))
    if agg == "sum":
        meas = rng.uniform(0, 10, n)
        delta = 30.0
    else:
        meas = np.abs(np.cumsum(rng.normal(0, 5, n))) + 10
        delta = 15.0
    idx = build_index_1d(keys, meas, agg, deg=deg, delta=delta)
    return idx, keys


def _queries(keys, nq, seed=1):
    rng = np.random.default_rng(seed)
    a = keys[rng.integers(0, len(keys), nq)]
    b = keys[rng.integers(0, len(keys), nq)]
    return np.minimum(a, b), np.maximum(a, b)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("deg", [1, 2, 3, 4])
@pytest.mark.parametrize("nq", [17, 256, 1000])
def test_poly_eval_matches_ref(dtype, deg, nq):
    idx, keys = _index("sum", deg)
    tbl = from_index(idx, dtype=dtype)
    q = keys[np.random.default_rng(2).integers(0, len(keys), nq)]
    out_k = np.asarray(poly_eval(tbl, q, backend="pallas"))
    out_r = np.asarray(poly_eval(tbl, q, backend="ref"))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-6, atol=1e-6)
    assert out_k.shape == (nq,)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("deg", [1, 2, 3])
@pytest.mark.parametrize("bq,bh", [(128, 256), (256, 512)])
def test_range_sum_matches_ref(dtype, deg, bq, bh):
    idx, keys = _index("sum", deg)
    tbl = from_index(idx, dtype=dtype, bh=bh)
    lq, uq = _queries(keys, 700)
    out_k = np.asarray(range_sum(tbl, lq, uq, backend="pallas", bq=bq, bh=bh))
    out_r = np.asarray(range_sum(tbl, lq, uq, backend="ref", bq=bq, bh=bh))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("deg", [2, 3])
def test_range_max_matches_ref(dtype, deg):
    idx, keys = _index("max", deg)
    tbl = from_index(idx, dtype=dtype)
    lq, uq = _queries(keys, 700)
    out_k = np.asarray(range_max(tbl, lq, uq, backend="pallas"))
    out_r = np.asarray(range_max(tbl, lq, uq, backend="ref"))
    rtol = 1e-4 if dtype == jnp.float32 else 1e-9
    np.testing.assert_allclose(out_k, out_r, rtol=rtol, atol=1e-3)


def test_kernel_f64_matches_core_sum():
    """At f64 the kernel path reproduces the core query path."""
    idx, keys = _index("sum", 2)
    tbl = from_index(idx, dtype=jnp.float64)
    lq, uq = _queries(keys, 500)
    out = np.asarray(range_sum(tbl, lq, uq, backend="pallas"))
    truth = np.asarray(query_sum(idx, lq, uq).answer)
    np.testing.assert_allclose(out, truth, rtol=1e-9, atol=1e-9)


def test_kernel_f64_matches_core_max():
    idx, keys = _index("max", 3)
    tbl = from_index(idx, dtype=jnp.float64)
    lq, uq = _queries(keys, 500)
    out = np.asarray(range_max(tbl, lq, uq, backend="pallas"))
    truth = np.asarray(query_max(idx, lq, uq).answer)
    np.testing.assert_allclose(out, truth, rtol=1e-9, atol=1e-9)


def test_kernel_f32_guarantee_holds():
    """The f32 kernel answer still satisfies the paper's bound with an FP
    slack proportional to the CF magnitude."""
    idx, keys = _index("sum", 2, n=20000)
    tbl = from_index(idx, dtype=jnp.float32)
    lq, uq = _queries(keys, 800)
    out = np.asarray(range_sum(tbl, lq, uq, backend="pallas"))
    ex = idx.exact_sum
    truth = np.asarray(ex.cf_at(jnp.asarray(uq)) - ex.cf_at(jnp.asarray(lq)))
    cf_scale = float(np.asarray(ex.cf).max())
    fp_slack = cf_scale * np.finfo(np.float32).eps * 8
    assert np.max(np.abs(out - truth)) <= 2 * idx.delta + fp_slack


def test_out_of_domain_queries_clamp():
    idx, keys = _index("sum", 2)
    tbl = from_index(idx, dtype=jnp.float64)
    lq = np.array([-1e9, keys[0], keys[-1]])
    uq = np.array([keys[5], 1e9, 1e9])
    out_k = np.asarray(range_sum(tbl, lq, uq, backend="pallas"))
    out_r = np.asarray(range_sum(tbl, lq, uq, backend="ref"))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-9)
    assert np.isfinite(out_k).all()
