"""Substrate units: optimizer, checkpoint manager, data pipeline,
gradient-compression quantization, train-step microbatching."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticTokens, length_stats
from repro.dist.compression import dequantize_int8, quantize_int8
from repro.optim import adamw_init, adamw_update, clip_by_global_norm


def test_adamw_converges_quadratic():
    """AdamW minimizes a simple quadratic."""
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)

    @jax.jit
    def step(state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(state.params)
        new, gn = adamw_update(state, grads, 0.05, weight_decay=0.0)
        return new

    for _ in range(300):
        state = step(state)
    np.testing.assert_allclose(np.asarray(state.params["w"]), target, atol=0.05)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(clipped)))
    assert abs(total - 1.0) < 1e-5
    assert float(gn) > 1.0


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": np.arange(10, dtype=np.float32), "b": {"c": np.eye(3)}}
    for s in (1, 2, 3):
        cm.save(s, tree)
    assert cm.steps() == [2, 3]          # keep=2 garbage-collects step 1
    out = cm.restore(tree, step=3)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_detects_corruption(tmp_path):
    import os
    cm = CheckpointManager(str(tmp_path))
    tree = {"a": np.arange(16, dtype=np.float32)}
    path = cm.save(5, tree)
    leaf = os.path.join(path, "leaf_0.npy")
    arr = np.load(leaf)
    arr[0] = 999
    np.save(leaf, arr)
    with pytest.raises(IOError):
        cm.restore(tree, step=5)


def test_pipeline_deterministic_replay():
    p = SyntheticTokens(vocab=100, seq_len=16, global_batch=4, seed=7)
    a = p.batch(12)
    b = p.batch(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch(13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_length_stats_polyfit_in_pipeline():
    """The paper's technique inside the data pipeline (DESIGN.md §5)."""
    rng = np.random.default_rng(0)
    lengths = rng.pareto(1.2, 50_000) * 100 + 10
    buckets = [(0, 128), (128, 512), (512, 2048), (2048, 1e9)]
    approx, idx = length_stats(lengths, buckets, delta=32.0)
    truth = np.array([((lengths > a) & (lengths <= b)).sum() for a, b in buckets])
    assert np.all(np.abs(approx - truth) <= 64.0 + 1e-6)


def test_int8_quantization_roundtrip():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 2, (512,)).astype(np.float32))
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


def test_train_step_microbatching_equivalent():
    """Grad accumulation over M microbatches == full-batch step (same data)."""
    from repro.configs import ARCHS
    from repro.models import init_model
    from repro.train import make_train_step

    cfg = ARCHS["qwen3-1.7b"].smoke()
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab)}
    s1, m1 = make_train_step(cfg, microbatches=1)(adamw_init(params), batch)
    s2, m2 = make_train_step(cfg, microbatches=4)(adamw_init(params), batch)
    # losses agree; params agree to accumulation tolerance (bf16 forward)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 5e-2
