"""Dry-run integration: a representative subset of (arch x shape x mesh)
cells must lower + compile in a 512-device subprocess (the full 80-cell
sweep runs via `python -m repro.launch.dryrun --mesh both`; committed
results in benchmarks/results/dryrun/)."""
import importlib.util
import json
import os
import subprocess
import sys

import jax
import pytest

# The dry-run subprocess forces a 512-device topology; running it (and
# auditing the committed sweep) is only meaningful on a multi-device
# container — single-device CI hosts skip (this replaces the old --ignore
# flags, so the CI invocation matches the ROADMAP tier-1 command).
pytestmark = [
    pytest.mark.skipif(
        jax.device_count() < 8,
        reason="dry-run cells need a container with >= 8 devices"),
    # the dry-run entrypoint still imports the seed's unshipped sharding
    # spec module (ROADMAP open item); skip rather than fail until it lands
    pytest.mark.skipif(
        importlib.util.find_spec("repro.dist.sharding") is None,
        reason="repro.dist.sharding not implemented yet (ROADMAP)"),
]

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
RESULTS = os.path.join(ROOT, "benchmarks", "results", "dryrun")


@pytest.mark.parametrize("arch,shape,mesh", [
    ("qwen3-1.7b", "train_4k", "single"),
    ("mamba2-130m", "long_500k", "single"),
    ("qwen3-moe-30b-a3b", "decode_32k", "multi"),
])
def test_dryrun_cell_compiles(arch, shape, mesh):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--mesh", mesh,
         "--arch", arch, "--shape", shape],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=1800)
    assert ": OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.load(open(os.path.join(RESULTS, f"{mesh}_{arch}_{shape}.json")))
    assert rec["status"] == "ok"
    assert rec["cost"]["flops_per_device"] > 0
    assert set(rec["roofline_terms_s"]) == {"compute_s", "memory_s",
                                            "collective_s"}


def test_committed_sweep_is_complete():
    """Every (10 arch x 4 shape x 2 mesh) cell has a result file, and every
    non-skipped cell compiled OK."""
    from repro.configs import ARCHS, SHAPES
    missing, bad = [], []
    for mesh in ("single", "multi"):
        for arch in ARCHS:
            for shape in SHAPES:
                p = os.path.join(RESULTS, f"{mesh}_{arch}_{shape}.json")
                if not os.path.exists(p):
                    missing.append((mesh, arch, shape))
                    continue
                rec = json.load(open(p))
                if rec["status"] == "error":
                    bad.append(rec["cell"])
    assert not missing, f"missing cells: {missing[:5]} (+{len(missing)} total)"
    assert not bad, f"failed cells: {bad}"
