"""repro.dist.compression: int8 quantize/dequantize contracts."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.dist.compression import dequantize_int8, quantize_int8  # noqa: E402


@pytest.mark.parametrize("shape", [(16,), (8, 32), (2, 3, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_round_trip_error_bound(shape, dtype):
    """|dequantize(quantize(x)) - x| <= scale/2 elementwise (round-to-
    nearest of symmetric per-tensor quantization)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3.0, shape), dtype)
    q, scale = quantize_int8(x)
    back = dequantize_int8(q, scale, dtype=dtype)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert err.max() <= float(scale) / 2 + 1e-7


def test_dtype_and_shape_preservation():
    x = jnp.asarray(np.linspace(-4, 4, 24).reshape(4, 6), jnp.float32)
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    assert q.shape == x.shape
    assert scale.dtype == x.dtype
    assert scale.shape == ()
    for out_dtype in (jnp.float32, jnp.float64, jnp.bfloat16):
        back = dequantize_int8(q, scale, dtype=out_dtype)
        assert back.dtype == out_dtype
        assert back.shape == x.shape


def test_codes_bounded_and_extremes_hit():
    """Codes stay in [-127, 127] and the absolute max maps to +-127."""
    x = jnp.asarray([0.5, -2.0, 4.0, -1.0], jnp.float32)
    q, scale = quantize_int8(x)
    qn = np.asarray(q)
    assert qn.min() >= -127 and qn.max() <= 127
    assert qn[2] == 127
    np.testing.assert_allclose(float(scale), 4.0 / 127.0, rtol=1e-6)


def test_all_zero_tensor():
    x = jnp.zeros((5, 5), jnp.float32)
    q, scale = quantize_int8(x)
    assert float(scale) == 0.0
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(dequantize_int8(q, scale)) == 0.0)


def test_jit_and_symmetry():
    """jit-safe, and quantization is sign-symmetric: q(-x) == -q(x)."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (32,)), jnp.float32)
    q1, s1 = jax.jit(quantize_int8)(x)
    q2, s2 = quantize_int8(x)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    assert float(s1) == float(s2)
    qneg, sneg = quantize_int8(-x)
    np.testing.assert_array_equal(np.asarray(qneg), -np.asarray(q2))
    assert float(sneg) == float(s2)
