"""Serving-engine tests (serve/engine.py): coalesced answers bit-identical
to serial execution, concurrent reader/writer pools, queue backpressure,
AOT-cache plan-swap invalidation, and shutdown/drain semantics.

Everything runs backend='ref' on small synthetic tables so the suite
stays CPU-cheap; the bit-identity assertions compare against the plain
``session.query`` path, which the engine must reproduce exactly (the
executors are elementwise per query, so admission batching may not
change a single bit).
"""
from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from repro.api import ErrorBudget, PolyFit, QuerySpec, TableSpec
from repro.serve import QueueFull, ServingEngine

N1 = 4000
N2 = 2000


@pytest.fixture(scope="module")
def session():
    rng = np.random.default_rng(0xE17)
    keys = np.sort(rng.uniform(0.0, 100.0, N1))
    vals = rng.uniform(0.0, 10.0, N1)
    xs = rng.uniform(0.0, 50.0, N2)
    ys = rng.uniform(0.0, 50.0, N2)
    ws = rng.uniform(1.0, 5.0, N2)
    b = ErrorBudget(abs=50.0, rel=0.01)
    m = ErrorBudget(abs=0.5, rel=0.01)
    return PolyFit.fit(
        {"sum": (keys, vals), "min": (keys, vals), "c2": (xs, ys),
         "mn2": (xs, ys, ws)},
        {"sum": TableSpec("sum", b, dynamic=True, capacity=256,
                          auto_refit=False),
         "min": TableSpec("min", m),
         "c2": TableSpec("count2d", b, dynamic=True, capacity=256,
                         auto_refit=False),
         "mn2": TableSpec("min2d", m)},
        backend="ref")


def _mixed_specs(rng, n):
    specs = []
    for _ in range(n):
        m = int(rng.integers(1, 5))
        kind = int(rng.integers(4))
        if kind == 0:
            lq = rng.uniform(0, 80, m)
            specs.append(QuerySpec.range("sum", lq, lq + 10.0))
        elif kind == 1:
            lq = rng.uniform(0, 80, m)
            specs.append(QuerySpec.range("min", lq, lq + 15.0))
        elif kind == 2:
            lx, ly = rng.uniform(0, 40, m), rng.uniform(0, 40, m)
            specs.append(QuerySpec.rect("c2", lx, lx + 8, ly, ly + 8))
        else:
            specs.append(QuerySpec.corner("mn2", rng.uniform(10, 50, m),
                                          rng.uniform(10, 50, m)))
    return specs


def _assert_identical(got, want):
    assert np.array_equal(np.asarray(got.answer), np.asarray(want.answer))
    assert np.array_equal(np.asarray(got.approx), np.asarray(want.approx))


def test_coalesced_bit_identical_to_serial(session):
    """A stream submitted through the queue (admission batching on) gives
    exactly the serial per-spec answers, across all four kinds including
    the newly exposed 1-D sum/min and 2-D min2d."""
    rng = np.random.default_rng(1)
    specs = _mixed_specs(rng, 40)
    serial = [session.query(s) for s in specs]
    eng = ServingEngine(session, start=False)
    futures = [eng.submit(s) for s in specs]   # all queued before serving
    eng.start()
    try:
        for fut, want in zip(futures, serial):
            _assert_identical(fut.result(timeout=120), want)
        st = eng.stats
        assert st.answered == len(specs)
        assert st.coalesced > 0          # batching actually kicked in
        assert st.dispatches < len(specs)
    finally:
        eng.shutdown()


def test_aot_cache_reuse_and_warmup(session):
    eng = ServingEngine(session)
    try:
        n = eng.warmup(max_bucket=128)
        assert n == 8                    # 4 tables x ladder {64, 128}
        assert eng.warmup(max_bucket=128) == 0   # idempotent
        c0 = eng.stats.aot_compiles
        rng = np.random.default_rng(2)
        for s in _mixed_specs(rng, 12):
            eng.query(s, timeout=120)
        st = eng.stats
        assert st.aot_compiles == c0     # warm ladder: zero new traces
        assert st.aot_hits >= 12 or st.dispatches < 12
    finally:
        eng.shutdown()


def test_plan_swap_precompiles_executables(session):
    """A merge/compaction stages the incoming plan's executables on the
    merge thread (``on_plan_swap`` listener), so the post-swap dispatch
    promotes instead of relowering: zero new compiles after a swap."""
    eng = ServingEngine(session)
    spec = QuerySpec.range("sum", 5.0, 60.0)
    try:
        before = eng.query(spec, timeout=120)
        eng.insert("sum", np.array([10.0, 20.0]),
                   np.array([7.0, 3.0]), wait=True)
        buffered = eng.query(spec, timeout=120)
        assert float(buffered.answer[0]) == pytest.approx(
            float(before.answer[0]) + 10.0)
        c0 = eng.stats.aot_compiles
        promo0 = eng.stats.aot_promotions
        eng.flush("sum")                 # merge -> plan swap
        assert eng.stats.aot_precompiles > 0   # staged pre-install
        merged = eng.query(spec, timeout=120)
        # the refit plan approximates anew: answers agree within the two
        # certified Q_abs bounds, not bitwise
        assert abs(float(merged.answer[0])
                   - float(buffered.answer[0])) <= 100.0
        st = eng.stats
        assert st.aot_compiles == c0           # zero new compiles post-swap
        assert st.aot_promotions > promo0      # served the staged executable
        # engine answers == session answers on the swapped plan too
        _assert_identical(merged, session.query(spec))
    finally:
        eng.shutdown()
        # leave the module-scoped session clean for the other tests
        session.flush("sum")


def test_concurrent_reader_pool_bit_identical(session):
    """Many reader threads hammering the queue still each get exactly
    their own serial answer (futures scatter per request)."""
    rng = np.random.default_rng(3)
    specs = _mixed_specs(rng, 60)
    serial = [session.query(s) for s in specs]
    eng = ServingEngine(session, workers=2)
    errors = []

    def reader(lo, hi):
        try:
            for i in range(lo, hi):
                got = eng.query(specs[i], timeout=120)
                _assert_identical(got, serial[i])
        except BaseException as e:       # pragma: no cover - surfaced below
            errors.append(e)

    try:
        threads = [threading.Thread(target=reader, args=(i, i + 15))
                   for i in range(0, 60, 15)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(180)
        assert not errors, errors
    finally:
        eng.shutdown()


def test_mixed_readers_writers_linearizable(session):
    """Concurrent readers + async writers: with writes staged through the
    engine, every read matches a serial replay of the write log at *some*
    prefix (monotone in time), and after a full drain the engine answer
    equals the serial answer of the complete log."""
    eng = ServingEngine(session)
    spec = QuerySpec.range("sum", 0.0, 100.0)
    base = float(session.query(spec).answer[0])
    chunks = 6
    chunk = 16
    per_chunk = 2.0 * chunk              # each record adds measure 2.0
    errors = []
    seen = []

    def writer():
        try:
            rng = np.random.default_rng(4)
            for _ in range(chunks):
                eng.insert("sum", rng.uniform(0, 100, chunk),
                           np.full(chunk, 2.0), wait=False)
                time.sleep(0.01)
        except BaseException as e:
            errors.append(e)

    def reader():
        try:
            for _ in range(12):
                seen.append(float(eng.query(spec, timeout=120).answer[0]))
        except BaseException as e:
            errors.append(e)

    try:
        threads = [threading.Thread(target=writer),
                   threading.Thread(target=reader)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(180)
        assert not errors, errors
        eng.drain_updates()
        final = float(eng.query(spec, timeout=120).answer[0])
        assert final == pytest.approx(base + chunks * per_chunk)
        # reads only ever see whole staged-chunk prefixes, in order
        tol = 1e-6 * max(1.0, abs(base))
        valid = [base + k * per_chunk for k in range(chunks + 1)]
        for v in seen:
            assert min(abs(v - x) for x in valid) < tol, (v, valid)
        assert seen == sorted(seen)      # write visibility is monotone
    finally:
        eng.shutdown()
        session.flush("sum")


def test_backpressure_reject_and_block(session):
    spec = QuerySpec.range("min", 0.0, 1.0)
    eng = ServingEngine(session, max_queue=4, admission="reject",
                        start=False)   # nothing drains: deterministic
    for _ in range(4):
        eng.submit(spec)
    with pytest.raises(QueueFull):
        eng.submit(spec)
    assert eng.stats.rejected == 1
    assert eng.queue_depth == 4
    eng.start()                          # drain the queued four
    eng.shutdown(drain=True)
    assert eng.stats.answered == 4

    blocking = ServingEngine(session, max_queue=2, admission="block",
                             start=False)
    blocking.submit(spec)
    blocking.submit(spec)
    with pytest.raises(QueueFull):       # block admission honors timeout
        blocking.submit(spec, timeout=0.05)
    blocking.start()
    blocking.shutdown(drain=True)


def test_shutdown_drain_answers_everything(session):
    rng = np.random.default_rng(5)
    specs = _mixed_specs(rng, 10)
    eng = ServingEngine(session, start=False)
    futures = [eng.submit(s) for s in specs]
    eng.insert("sum", np.array([1.0]), np.array([1.0]), wait=False)
    eng.start()
    eng.shutdown(drain=True)             # must answer + apply everything
    assert all(f.done() and f.exception() is None for f in futures)
    assert eng.staged_depth == 0
    with pytest.raises(RuntimeError):
        eng.submit(specs[0])
    eng.shutdown()                       # idempotent
    session.flush("sum")


def test_shutdown_no_drain_cancels_queued(session):
    spec = QuerySpec.range("sum", 0.0, 1.0)
    eng = ServingEngine(session, start=False)
    futures = [eng.submit(spec) for _ in range(5)]
    eng.shutdown(drain=False)
    for f in futures:
        assert isinstance(f.exception(timeout=5), RuntimeError)


def test_delete_error_surfaces(session):
    eng = ServingEngine(session)
    try:
        with pytest.raises(KeyError):    # no live occurrence of key 1e9
            eng.delete("sum", np.array([1e9]), wait=True)
        eng.delete("sum", np.array([2e9]), wait=False)
        with pytest.raises(KeyError):    # deferred error lands on drain
            eng.drain_updates()
    finally:
        eng.shutdown()


def test_update_normalization_errors(session):
    eng = ServingEngine(session, start=False)
    with pytest.raises(ValueError):
        eng.insert("sum", np.array([1.0]), wait=False)   # measures missing
    with pytest.raises(ValueError):
        eng.delete("c2", np.array([1.0]), wait=False)    # ys missing
    with pytest.raises(RuntimeError):                    # static table
        eng.insert("min", np.array([1.0]), np.array([1.0]), wait=False)
