"""Unit tests for minimax polynomial fitting (paper §4.1 / Eq. 9-10)."""
import numpy as np

from repro.core import (continuum_error, eval_poly, fit_lstsq,
                        fit_minimax_lawson, fit_minimax_lp, lawson_batched,
                        max_error)
import jax.numpy as jnp


def test_lp_matches_chebyshev_closed_form():
    """Best deg-2 minimax fit of x^3 on [-1,1] has error exactly 1/4
    (Chebyshev equioscillation: x^3 - (3/4)x = T_3(x)/4)."""
    xs = np.cos(np.pi * np.arange(2000) / 1999)  # dense grid incl endpoints
    xs = np.sort(xs)
    F = xs**3
    m = fit_minimax_lp(xs, F, deg=2)
    assert abs(m.err - 0.25) < 1e-6
    # the optimal quadratic approximation of x^3 is the line (3/4)x
    assert np.allclose(m.coeffs, [0, 0.75, 0], atol=1e-5)


def test_lp_interpolates_small_sets():
    xs = np.array([0.0, 1.0, 2.0])
    F = np.array([5.0, -1.0, 3.0])
    m = fit_minimax_lp(xs, F, deg=2)
    assert m.err < 1e-9
    assert np.allclose(m(xs), F, atol=1e-9)


def test_lawson_converges_to_lp():
    rng = np.random.default_rng(3)
    xs = np.sort(rng.uniform(0, 10, 200))
    F = np.sin(xs) * 50 + xs**2
    for deg in (1, 2, 3):
        m_lp = fit_minimax_lp(xs, F, deg)
        m_la = fit_minimax_lawson(xs, F, deg, iters=200)
        # Lawson upper-bounds the optimum and converges close to it
        assert m_la.err >= m_lp.err - 1e-9
        assert m_la.err <= m_lp.err * 1.05 + 1e-9


def test_lstsq_upper_bounds_minimax():
    rng = np.random.default_rng(4)
    xs = np.sort(rng.uniform(0, 1, 100))
    F = rng.normal(0, 1, 100)
    for deg in (1, 2, 3):
        assert fit_lstsq(xs, F, deg).err >= fit_minimax_lp(xs, F, deg).err - 1e-12


def test_lawson_batched_matches_single():
    rng = np.random.default_rng(5)
    B, L, deg = 8, 64, 2
    u = np.sort(rng.uniform(-1, 1, (B, L)), axis=1)
    F = np.cumsum(rng.uniform(0, 1, (B, L)), axis=1)
    valid = np.ones((B, L))
    coeffs, errs = lawson_batched(jnp.asarray(u), jnp.asarray(F),
                                  jnp.asarray(valid), deg, iters=80)
    coeffs, errs = np.asarray(coeffs), np.asarray(errs)
    for b in range(B):
        resid = np.abs(F[b] - eval_poly(coeffs[b], u[b]))
        assert abs(errs[b] - resid.max()) < 1e-8


def test_continuum_error_catches_bulge():
    """A parabola interpolating 3 points can exceed the key-error bound
    between keys; continuum_error must see it."""
    # keys clustered at the left, one far right: interpolation bulges
    keys = np.array([0.0, 0.01, 1.0])
    vals = np.array([0.0, 1.0, 0.0])
    m = fit_minimax_lp(keys, vals, deg=2)
    assert m.err < 1e-8  # interpolates exactly at the keys
    ce = continuum_error(m, keys, vals)
    assert ce > 5.0  # the bulge between keys is large


def test_rescale_conditioning():
    # fits on raw vs scaled keys: scaled must stay accurate at deg 4
    rng = np.random.default_rng(6)
    keys = np.sort(rng.uniform(1e9, 1e9 + 1000, 300))  # huge offset
    F = np.cumsum(rng.uniform(0, 1, 300))
    m = fit_minimax_lp(keys, F, deg=4)
    assert max_error(m, keys, F) <= m.err + 1e-6
    assert m.err < np.ptp(F)  # sane fit despite raw keys ~1e9
