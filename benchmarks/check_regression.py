"""CI benchmark-regression gate: compare a fresh bench run to the
committed baseline.

Reads two BENCH-style JSON histories (lists of {"meta", "results"}
records), pairs the candidate's latest record with every baseline record
whose meta shape matches (same n/nq/.../device), and fails with exit
code 1 if any shared metric regressed by more than ``--threshold``
(default 2x) against the per-metric *envelope* (max over the matching
records — sub-microsecond metrics jitter ~2x run to run, so the envelope,
fed by a few committed samples, absorbs CI-runner noise without loosening
the threshold).  ``--require-prefix`` fails (exit 2) when an expected
metric family is missing from the candidate entirely.  Exit code 2
otherwise means the inputs could not be paired — a config error, not a
perf regression.

Usage (the ci.yml benchmark-smoke job):

    python -m benchmarks.bench_kernels --tiny --out bench_tiny.json
    python -m benchmarks.check_regression \
        --baseline BENCH_engine.json --candidate bench_tiny.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# capacity pairs bench_updates records; hs/hs2/nqh pair the H-sweep shape;
# shard_* pair the sharded-plan sweep; dim separates bench_updates' 2-D
# mode from the 1-D records; lsm/levels pair the LSM worst-case records
# (updates*.lsm.* metrics are already max-aggregated per op, so they ride
# the same max envelope as every other family); n1/n2/nreq/rate/backend
# pair the bench_serve open-loop shape; window (the ring size) pairs the
# epoch-ring window records (records missing a key on both sides still
# pair — .get(None) == .get(None))
MATCH_META = ("n", "nq", "n2", "nq2", "capacity", "hs", "hs2", "nqh",
              "shard_h", "shard_nq", "shard_s", "dim", "lsm", "levels",
              "n1", "nreq", "rate", "backend", "window", "device")


def _load_history(path: str):
    try:
        history = json.loads(pathlib.Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"[check_regression] cannot read {path}: {e}")
        sys.exit(2)
    if not isinstance(history, list) or not history:
        print(f"[check_regression] {path}: empty or malformed history")
        sys.exit(2)
    return history


def _matching_baselines(history, cand_meta):
    """All baseline records whose meta shape matches the candidate's.

    The gate compares against the per-metric *envelope* (max) across them:
    sub-microsecond metrics jitter ~2x run to run on shared CI hosts, so a
    single unlucky baseline sample would make the threshold fire on noise.
    Committing a couple of tiny-bench records per machine widens the
    envelope to the observed noise band without loosening the threshold.
    """
    want = {k: cand_meta.get(k) for k in MATCH_META}
    return [rec for rec in history
            if all(rec.get("meta", {}).get(k) == v
                   for k, v in want.items())]


def compare(baseline_path: str, candidate_path: str, threshold: float,
            require_prefixes=()) -> int:
    cand = _load_history(candidate_path)[-1]
    bases = _matching_baselines(_load_history(baseline_path),
                                cand.get("meta", {}))
    if not bases:
        print("[check_regression] no baseline record matches candidate "
              f"meta {cand.get('meta')}; re-run the full benchmark and "
              "commit its record first")
        return 2

    # a metric family silently vanishing from the bench must fail the gate
    # (e.g. the H-sweep entries the locate->gather acceptance rides on)
    names = {r["name"] for r in cand["results"]}
    missing = [p for p in require_prefixes
               if not any(n.startswith(p) for n in names)]
    if missing:
        print("[check_regression] candidate has no metrics under required "
              f"prefix(es): {', '.join(missing)}")
        return 2

    base_by_name = {}
    for rec in bases:
        for r in rec["results"]:
            base_by_name[r["name"]] = max(base_by_name.get(r["name"], 0.0),
                                          r["us_per_query"])
    print(f"[check_regression] baseline envelope over {len(bases)} matching "
          "record(s)")
    failures = []
    compared = 0
    for r in cand["results"]:
        name = r["name"]
        if name not in base_by_name:
            print(f"  NEW     {name}: {r['us_per_query']:.3f}us "
                  "(no baseline yet)")
            continue
        compared += 1
        ref = base_by_name[name]
        got = r["us_per_query"]
        ratio = got / ref if ref > 0 else float("inf")
        status = "FAIL" if ratio > threshold else "ok"
        print(f"  {status:7s} {name}: {got:.3f}us vs baseline "
              f"{ref:.3f}us ({ratio:.2f}x)")
        if ratio > threshold:
            failures.append((name, ratio))
    if compared == 0:
        print("[check_regression] no shared metrics between candidate and "
              "baseline")
        return 2
    if failures:
        print(f"[check_regression] {len(failures)} metric(s) regressed "
              f"beyond {threshold}x: "
              + ", ".join(f"{n} ({r:.2f}x)" for n, r in failures))
        return 1
    print(f"[check_regression] OK — {compared} metrics within "
          f"{threshold}x of baseline")
    return 0


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--baseline", required=True,
                   help="committed BENCH history (e.g. BENCH_engine.json)")
    p.add_argument("--candidate", required=True,
                   help="fresh run's BENCH history (e.g. bench_tiny.json)")
    p.add_argument("--threshold", type=float, default=2.0,
                   help="fail when candidate/baseline exceeds this ratio")
    p.add_argument("--require-prefix", action="append", default=[],
                   help="fail (exit 2) when the candidate has no metric "
                        "under this name prefix (repeatable)")
    args = p.parse_args()
    sys.exit(compare(args.baseline, args.candidate, args.threshold,
                     require_prefixes=args.require_prefix))


if __name__ == "__main__":
    main()
