"""CI benchmark-regression gate: compare a fresh bench run to the
committed baseline.

Reads two BENCH-style JSON histories (lists of {"meta", "results"}
records), pairs the candidate's latest record with the latest baseline
record whose meta shape matches (same n/nq/n2/nq2/device), and fails with
exit code 1 if any shared metric regressed by more than ``--threshold``
(default 2x, absorbing CI-runner noise).  Exit code 2 means the inputs
could not be paired — a config error, not a perf regression.

Usage (the ci.yml benchmark-smoke job):

    python -m benchmarks.bench_kernels --tiny --out bench_tiny.json
    python -m benchmarks.check_regression \
        --baseline BENCH_engine.json --candidate bench_tiny.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

MATCH_META = ("n", "nq", "n2", "nq2", "device")


def _load_history(path: str):
    try:
        history = json.loads(pathlib.Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"[check_regression] cannot read {path}: {e}")
        sys.exit(2)
    if not isinstance(history, list) or not history:
        print(f"[check_regression] {path}: empty or malformed history")
        sys.exit(2)
    return history


def _matching_baseline(history, cand_meta):
    """Latest baseline record whose meta shape matches the candidate's."""
    want = {k: cand_meta.get(k) for k in MATCH_META}
    for rec in reversed(history):
        meta = rec.get("meta", {})
        if all(meta.get(k) == v for k, v in want.items()):
            return rec
    return None


def compare(baseline_path: str, candidate_path: str,
            threshold: float) -> int:
    cand = _load_history(candidate_path)[-1]
    base = _matching_baseline(_load_history(baseline_path),
                              cand.get("meta", {}))
    if base is None:
        print("[check_regression] no baseline record matches candidate "
              f"meta {cand.get('meta')}; re-run the full benchmark and "
              "commit its record first")
        return 2

    base_by_name = {r["name"]: r["us_per_query"] for r in base["results"]}
    failures = []
    compared = 0
    for r in cand["results"]:
        name = r["name"]
        if name not in base_by_name:
            print(f"  NEW     {name}: {r['us_per_query']:.3f}us "
                  "(no baseline yet)")
            continue
        compared += 1
        ref = base_by_name[name]
        got = r["us_per_query"]
        ratio = got / ref if ref > 0 else float("inf")
        status = "FAIL" if ratio > threshold else "ok"
        print(f"  {status:7s} {name}: {got:.3f}us vs baseline "
              f"{ref:.3f}us ({ratio:.2f}x)")
        if ratio > threshold:
            failures.append((name, ratio))
    if compared == 0:
        print("[check_regression] no shared metrics between candidate and "
              "baseline")
        return 2
    if failures:
        print(f"[check_regression] {len(failures)} metric(s) regressed "
              f"beyond {threshold}x: "
              + ", ".join(f"{n} ({r:.2f}x)" for n, r in failures))
        return 1
    print(f"[check_regression] OK — {compared} metrics within "
          f"{threshold}x of baseline")
    return 0


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--baseline", required=True,
                   help="committed BENCH history (e.g. BENCH_engine.json)")
    p.add_argument("--candidate", required=True,
                   help="fresh run's BENCH history (e.g. bench_tiny.json)")
    p.add_argument("--threshold", type=float, default=2.0,
                   help="fail when candidate/baseline exceeds this ratio")
    args = p.parse_args()
    sys.exit(compare(args.baseline, args.candidate, args.threshold))


if __name__ == "__main__":
    main()
