"""Dynamic-engine benchmark: update throughput + post-update query latency.

Measures, per backend, on the TWEET 1-D COUNT workload:

* buffered insert/delete throughput (records/s into the delta buffer);
* query latency with the delta buffer empty, half full and full (the
  fused delta-scan correction's cost as the buffer fills);
* merge latency (selective refit + plan install) and the query latency on
  the freshly installed plan.

Appends one timestamped record per run to ``BENCH_updates.json`` at the
repo root (same history format as ``BENCH_engine.json``), so the update
path's perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import pathlib
import platform
import time

import numpy as np
import jax
import jax.numpy as jnp

from .common import dataset, emit_history, row, time_fn

_BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_updates.json"


def _emit_json(results, meta, out_path=None):
    emit_history(results, meta, out_path or _BENCH_JSON, "bench_updates")


def run(n=100_000, nq=2048, capacity=2048, backends=("xla", "pallas", "ref"),
        out_path=None):
    from repro.core import build_index_1d
    from repro.data import make_queries_1d
    from repro.engine import DynamicEngine

    rows = []
    results = []

    def record(name, value, derived=""):
        rows.append(row(name, value, derived))
        results.append({"name": name, "us_per_query": value,
                        "derived": derived})

    keys, _ = dataset("tweet", n)
    lq, uq = map(jnp.asarray, make_queries_1d(keys, nq))
    idx = build_index_1d(keys, None, "count", deg=2, delta=50.0)
    rng = np.random.default_rng(0xD15C)
    batch = 256
    lo, hi = float(keys.min()), float(keys.max())

    for backend in backends:
        # warm the append-op compile cache per backend on a throwaway
        # engine: backend-gated buffer structures (sparse table, merge-sort
        # tree) trace on the backend's first insert, and those one-off
        # compiles must not land on the timed batches below
        warm = DynamicEngine(idx, backend=backend, capacity=capacity,
                             auto_refit=False)
        for _ in range(2):
            warm.insert(rng.uniform(lo, hi, batch))
            warm.insert(rng.uniform(lo, hi, capacity - batch))
            warm.flush()
        jax.block_until_ready(warm._state[1].ins_keys)

        # -- chunked insert throughput: one fused jitted append for a
        # full-capacity chunk — the serving engine's drain granularity ----
        times = []
        for _ in range(5):
            chunked = DynamicEngine(idx, backend=backend, capacity=capacity,
                                    auto_refit=False)
            big = rng.uniform(lo, hi, capacity)
            t0 = time.perf_counter()
            chunked.insert(big)
            jax.block_until_ready(chunked._state[1].ins_keys)
            times.append(time.perf_counter() - t0)
        dt = float(np.median(times))
        record(f"updates.insert_chunked.{backend}", dt / capacity * 1e6,
               f"recs_per_s={capacity / dt:.0f}")

        dyn = DynamicEngine(idx, backend=backend, capacity=capacity,
                            auto_refit=False)
        # -- buffered insert throughput (records/s): median per-batch time,
        # so a one-off host hiccup cannot trip the CI regression gate ------
        n_batches = capacity // batch
        ins = [rng.uniform(lo, hi, batch) for _ in range(n_batches)]
        half = n_batches // 2
        times = []
        for b in ins[:half]:
            t0 = time.perf_counter()
            dyn.insert(b)
            jax.block_until_ready(dyn._state[1].ins_keys)
            times.append(time.perf_counter() - t0)
        dt = float(np.median(times))
        record(f"updates.insert.{backend}", dt / batch * 1e6,
               f"recs_per_s={batch / dt:.0f}")

        # -- query latency at half / full fill ----------------------------
        t, _ = time_fn(lambda l, u: dyn.sum(l, u), lq, uq)
        record(f"updates.query_halffull.{backend}", t / nq * 1e6,
               f"pending={dyn.n_pending}")
        for b in ins[half:]:
            dyn.insert(b)
        t, _ = time_fn(lambda l, u: dyn.sum(l, u), lq, uq)
        record(f"updates.query_full.{backend}", t / nq * 1e6,
               f"pending={dyn.n_pending}")

        # -- merge (selective refit + install) ----------------------------
        t0 = time.perf_counter()
        dyn.flush()
        record(f"updates.merge.{backend}",
               (time.perf_counter() - t0) * 1e6,
               f"h={dyn.index.h}")

        # -- post-merge query latency (buffer empty again) ----------------
        t, _ = time_fn(lambda l, u: dyn.sum(l, u), lq, uq)
        record(f"updates.query_postmerge.{backend}", t / nq * 1e6)

    _emit_json(results, {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n": n, "nq": nq, "capacity": capacity,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }, out_path)
    return rows


def run2d(n=40_000, nq=1024, capacity=1024,
          backends=("xla", "pallas", "ref"), out_path=None):
    """DynamicEngine2D sweep (``--dim 2``): sum2d insert/delete throughput,
    buffered-query latency, and the selective-refit merge on OSM points
    with synthetic per-node weights.  Metric names carry the ``updates2d.``
    prefix and the record's meta carries ``dim=2`` so check_regression
    pairs it only with 2-D baselines."""
    from repro.core import build_index_2d
    from repro.data import make_queries_2d, osm_points
    from repro.engine import DynamicEngine2D

    rows = []
    results = []

    def record(name, value, derived=""):
        rows.append(row(name, value, derived))
        results.append({"name": name, "us_per_query": value,
                        "derived": derived})

    px, py = osm_points(n)
    rng = np.random.default_rng(0x2DB)
    w = 50.0 + 20.0 * np.sin(px / 7.0) + 15.0 * np.cos(py / 11.0)
    # ~1% relative tightness in measure units (matches the 1-D bench shape)
    delta = 0.01 * float(np.abs(w).sum())
    idx = build_index_2d(px, py, measures=w, agg="sum2d", deg=2,
                         delta=delta, max_depth=8)
    q = tuple(map(jnp.asarray, make_queries_2d(px, py, nq)))
    batch = 128
    x0, x1 = float(px.min()), float(px.max())
    y0, y1 = float(py.min()), float(py.max())

    for backend in backends:
        # per-backend warmup: the pallas buffer maintains merge-sort-tree
        # levels the xla path never traces, so a shared warm engine would
        # leave the pallas append compile on the first timed batch (the
        # source of the old ~480x updates2d.insert.pallas artifact)
        warm = DynamicEngine2D(idx, backend=backend, capacity=capacity,
                               auto_refit=False)
        for _ in range(2):
            warm.insert(rng.uniform(x0, x1, batch),
                        rng.uniform(y0, y1, batch),
                        rng.uniform(0, 100, batch))
            warm.insert(rng.uniform(x0, x1, capacity - batch),
                        rng.uniform(y0, y1, capacity - batch),
                        rng.uniform(0, 100, capacity - batch))
            warm.flush()
        jax.block_until_ready(warm._state[1].ins_x)

        # -- chunked insert throughput (one fused append per chunk) -------
        times = []
        for _ in range(5):
            chunked = DynamicEngine2D(idx, backend=backend,
                                      capacity=capacity, auto_refit=False)
            big = (rng.uniform(x0, x1, capacity),
                   rng.uniform(y0, y1, capacity),
                   rng.uniform(0, 100, capacity))
            t0 = time.perf_counter()
            chunked.insert(*big)
            jax.block_until_ready(chunked._state[1].ins_x)
            times.append(time.perf_counter() - t0)
        dt = float(np.median(times))
        record(f"updates2d.insert_chunked.{backend}", dt / capacity * 1e6,
               f"recs_per_s={capacity / dt:.0f}")

        dyn = DynamicEngine2D(idx, backend=backend, capacity=capacity,
                              auto_refit=False)
        n_batches = capacity // batch
        ins = [(rng.uniform(x0, x1, batch), rng.uniform(y0, y1, batch),
                rng.uniform(0, 100, batch)) for _ in range(n_batches)]
        half = n_batches // 2
        times = []
        for b in ins[:half]:
            t0 = time.perf_counter()
            dyn.insert(*b)
            jax.block_until_ready(dyn._state[1].ins_x)
            times.append(time.perf_counter() - t0)
        dt = float(np.median(times))
        record(f"updates2d.insert.{backend}", dt / batch * 1e6,
               f"recs_per_s={batch / dt:.0f}")

        t, _ = time_fn(lambda *r: dyn.sum2d(*r), *q)
        record(f"updates2d.query_halffull.{backend}", t / nq * 1e6,
               f"pending={dyn.n_pending}")
        for b in ins[half:]:
            dyn.insert(*b)
        dyn.delete(px[: batch // 2], py[: batch // 2])
        t, _ = time_fn(lambda *r: dyn.sum2d(*r), *q)
        record(f"updates2d.query_full.{backend}", t / nq * 1e6,
               f"pending={dyn.n_pending}")

        # -- merge: the selective leaf refit + plan install ---------------
        t0 = time.perf_counter()
        dyn.flush()
        st = dyn.last_refit_stats or {}
        record(f"updates2d.merge.{backend}",
               (time.perf_counter() - t0) * 1e6,
               f"refit={st.get('refit')}/{st.get('n_leaves')}"
               f";split={st.get('split')}")

        t, _ = time_fn(lambda *r: dyn.sum2d(*r), *q)
        record(f"updates2d.query_postmerge.{backend}", t / nq * 1e6)

    _emit_json(results, {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n": n, "nq": nq, "capacity": capacity, "dim": 2,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }, out_path)
    return rows


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="small shapes for CI smoke runs")
    p.add_argument("--dim", type=int, default=1, choices=(1, 2),
                   help="1: DynamicEngine on TWEET (default); 2: "
                        "DynamicEngine2D sum2d on OSM (selective refit)")
    p.add_argument("--out", default=None,
                   help="write the JSON record here instead of the "
                        "committed BENCH_updates.json")
    args = p.parse_args()
    if args.dim == 2:
        if args.tiny:
            run2d(n=8_000, nq=512, capacity=512, out_path=args.out)
        else:
            run2d(out_path=args.out)
    elif args.tiny:
        run(n=30_000, nq=1024, capacity=1024, out_path=args.out)
    else:
        run(out_path=args.out)


if __name__ == "__main__":
    main()
