"""Dynamic-engine benchmark: update throughput + post-update query latency.

Measures, per backend, on the TWEET 1-D COUNT workload:

* buffered insert/delete throughput (records/s into the delta buffer);
* query latency with the delta buffer empty, half full and full (the
  fused delta-scan correction's cost as the buffer fills);
* merge latency (selective refit + plan install) and the query latency on
  the freshly installed plan.

Appends one timestamped record per run to ``BENCH_updates.json`` at the
repo root (same history format as ``BENCH_engine.json``), so the update
path's perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import pathlib
import platform
import time

import numpy as np
import jax
import jax.numpy as jnp

from .common import dataset, emit_history, row, time_fn

_BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_updates.json"


def _emit_json(results, meta, out_path=None):
    emit_history(results, meta, out_path or _BENCH_JSON, "bench_updates")


def run(n=100_000, nq=2048, capacity=2048, backends=("xla", "pallas", "ref"),
        out_path=None):
    from repro.core import build_index_1d
    from repro.data import make_queries_1d
    from repro.engine import DynamicEngine

    rows = []
    results = []

    def record(name, value, derived=""):
        rows.append(row(name, value, derived))
        results.append({"name": name, "us_per_query": value,
                        "derived": derived})

    keys, _ = dataset("tweet", n)
    lq, uq = map(jnp.asarray, make_queries_1d(keys, nq))
    idx = build_index_1d(keys, None, "count", deg=2, delta=50.0)
    rng = np.random.default_rng(0xD15C)
    batch = 256
    lo, hi = float(keys.min()), float(keys.max())

    for backend in backends:
        # warm the append-op compile cache per backend on a throwaway
        # engine: backend-gated buffer structures (sparse table, merge-sort
        # tree) trace on the backend's first insert, and those one-off
        # compiles must not land on the timed batches below
        warm = DynamicEngine(idx, backend=backend, capacity=capacity,
                             auto_refit=False)
        for _ in range(2):
            warm.insert(rng.uniform(lo, hi, batch))
            warm.insert(rng.uniform(lo, hi, capacity - batch))
            warm.flush()
        jax.block_until_ready(warm._state[1].ins_keys)

        # -- chunked insert throughput: one fused jitted append for a
        # full-capacity chunk — the serving engine's drain granularity ----
        times = []
        for _ in range(5):
            chunked = DynamicEngine(idx, backend=backend, capacity=capacity,
                                    auto_refit=False)
            big = rng.uniform(lo, hi, capacity)
            t0 = time.perf_counter()
            chunked.insert(big)
            jax.block_until_ready(chunked._state[1].ins_keys)
            times.append(time.perf_counter() - t0)
        dt = float(np.median(times))
        record(f"updates.insert_chunked.{backend}", dt / capacity * 1e6,
               f"recs_per_s={capacity / dt:.0f}")

        dyn = DynamicEngine(idx, backend=backend, capacity=capacity,
                            auto_refit=False)
        # -- buffered insert throughput (records/s): median per-batch time,
        # so a one-off host hiccup cannot trip the CI regression gate ------
        n_batches = capacity // batch
        ins = [rng.uniform(lo, hi, batch) for _ in range(n_batches)]
        half = n_batches // 2
        times = []
        for b in ins[:half]:
            t0 = time.perf_counter()
            dyn.insert(b)
            jax.block_until_ready(dyn._state[1].ins_keys)
            times.append(time.perf_counter() - t0)
        dt = float(np.median(times))
        record(f"updates.insert.{backend}", dt / batch * 1e6,
               f"recs_per_s={batch / dt:.0f}")

        # -- query latency at half / full fill ----------------------------
        t, _ = time_fn(lambda l, u: dyn.sum(l, u), lq, uq)
        record(f"updates.query_halffull.{backend}", t / nq * 1e6,
               f"pending={dyn.n_pending}")
        for b in ins[half:]:
            dyn.insert(b)
        t, _ = time_fn(lambda l, u: dyn.sum(l, u), lq, uq)
        record(f"updates.query_full.{backend}", t / nq * 1e6,
               f"pending={dyn.n_pending}")

        # -- merge (selective refit + install) ----------------------------
        t0 = time.perf_counter()
        dyn.flush()
        record(f"updates.merge.{backend}",
               (time.perf_counter() - t0) * 1e6,
               f"h={dyn.index.h}")

        # -- post-merge query latency (buffer empty again) ----------------
        t, _ = time_fn(lambda l, u: dyn.sum(l, u), lq, uq)
        record(f"updates.query_postmerge.{backend}", t / nq * 1e6)

    _emit_json(results, {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n": n, "nq": nq, "capacity": capacity,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }, out_path)
    return rows


def run2d(n=40_000, nq=1024, capacity=1024,
          backends=("xla", "pallas", "ref"), out_path=None):
    """DynamicEngine2D sweep (``--dim 2``): sum2d insert/delete throughput,
    buffered-query latency, and the selective-refit merge on OSM points
    with synthetic per-node weights.  Metric names carry the ``updates2d.``
    prefix and the record's meta carries ``dim=2`` so check_regression
    pairs it only with 2-D baselines."""
    from repro.core import build_index_2d
    from repro.data import make_queries_2d, osm_points
    from repro.engine import DynamicEngine2D

    rows = []
    results = []

    def record(name, value, derived=""):
        rows.append(row(name, value, derived))
        results.append({"name": name, "us_per_query": value,
                        "derived": derived})

    px, py = osm_points(n)
    rng = np.random.default_rng(0x2DB)
    w = 50.0 + 20.0 * np.sin(px / 7.0) + 15.0 * np.cos(py / 11.0)
    # ~1% relative tightness in measure units (matches the 1-D bench shape)
    delta = 0.01 * float(np.abs(w).sum())
    idx = build_index_2d(px, py, measures=w, agg="sum2d", deg=2,
                         delta=delta, max_depth=8)
    q = tuple(map(jnp.asarray, make_queries_2d(px, py, nq)))
    batch = 128
    x0, x1 = float(px.min()), float(px.max())
    y0, y1 = float(py.min()), float(py.max())

    for backend in backends:
        # per-backend warmup: the pallas buffer maintains merge-sort-tree
        # levels the xla path never traces, so a shared warm engine would
        # leave the pallas append compile on the first timed batch (the
        # source of the old ~480x updates2d.insert.pallas artifact)
        warm = DynamicEngine2D(idx, backend=backend, capacity=capacity,
                               auto_refit=False)
        for _ in range(2):
            warm.insert(rng.uniform(x0, x1, batch),
                        rng.uniform(y0, y1, batch),
                        rng.uniform(0, 100, batch))
            warm.insert(rng.uniform(x0, x1, capacity - batch),
                        rng.uniform(y0, y1, capacity - batch),
                        rng.uniform(0, 100, capacity - batch))
            warm.flush()
        jax.block_until_ready(warm._state[1].ins_x)

        # -- chunked insert throughput (one fused append per chunk) -------
        times = []
        for _ in range(5):
            chunked = DynamicEngine2D(idx, backend=backend,
                                      capacity=capacity, auto_refit=False)
            big = (rng.uniform(x0, x1, capacity),
                   rng.uniform(y0, y1, capacity),
                   rng.uniform(0, 100, capacity))
            t0 = time.perf_counter()
            chunked.insert(*big)
            jax.block_until_ready(chunked._state[1].ins_x)
            times.append(time.perf_counter() - t0)
        dt = float(np.median(times))
        record(f"updates2d.insert_chunked.{backend}", dt / capacity * 1e6,
               f"recs_per_s={capacity / dt:.0f}")

        dyn = DynamicEngine2D(idx, backend=backend, capacity=capacity,
                              auto_refit=False)
        n_batches = capacity // batch
        ins = [(rng.uniform(x0, x1, batch), rng.uniform(y0, y1, batch),
                rng.uniform(0, 100, batch)) for _ in range(n_batches)]
        half = n_batches // 2
        times = []
        for b in ins[:half]:
            t0 = time.perf_counter()
            dyn.insert(*b)
            jax.block_until_ready(dyn._state[1].ins_x)
            times.append(time.perf_counter() - t0)
        dt = float(np.median(times))
        record(f"updates2d.insert.{backend}", dt / batch * 1e6,
               f"recs_per_s={batch / dt:.0f}")

        t, _ = time_fn(lambda *r: dyn.sum2d(*r), *q)
        record(f"updates2d.query_halffull.{backend}", t / nq * 1e6,
               f"pending={dyn.n_pending}")
        for b in ins[half:]:
            dyn.insert(*b)
        dyn.delete(px[: batch // 2], py[: batch // 2])
        t, _ = time_fn(lambda *r: dyn.sum2d(*r), *q)
        record(f"updates2d.query_full.{backend}", t / nq * 1e6,
               f"pending={dyn.n_pending}")

        # -- merge: the selective leaf refit + plan install ---------------
        t0 = time.perf_counter()
        dyn.flush()
        st = dyn.last_refit_stats or {}
        record(f"updates2d.merge.{backend}",
               (time.perf_counter() - t0) * 1e6,
               f"refit={st.get('refit')}/{st.get('n_leaves')}"
               f";split={st.get('split')}")

        t, _ = time_fn(lambda *r: dyn.sum2d(*r), *q)
        record(f"updates2d.query_postmerge.{backend}", t / nq * 1e6)

    _emit_json(results, {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n": n, "nq": nq, "capacity": capacity, "dim": 2,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }, out_path)
    return rows


def run_window(n=96_000, nq=2048, ring=8, epochs=6, capacity=16384,
               out_path=None):
    """Epoch-ring window sweep (``--window``): ingest throughput into the
    open epoch, advance (seal + minimax fit) latency, and query latency
    over the full retained window vs a single sealed epoch.  Metric names
    carry the ``updates.window.`` prefix and the record's meta carries the
    ring size under ``window`` so check_regression pairs it only with
    window baselines."""
    from repro.data import make_queries_1d
    from repro.engine import WindowEngine

    rows = []
    results = []

    def record(name, value, derived=""):
        rows.append(row(name, value, derived))
        results.append({"name": name, "us_per_query": value,
                        "derived": derived})

    keys, _ = dataset("tweet", n)
    per = n // epochs
    parts = [np.asarray(keys[i * per:(i + 1) * per]) for i in range(epochs)]
    lq, uq = map(jnp.asarray, make_queries_1d(keys, nq))
    assert per <= capacity, (per, capacity)

    def make():
        return WindowEngine(parts[0], agg="count", delta=50.0, ring=ring,
                            capacity=capacity)

    # warm the seal-fit + multi-level query compiles on a throwaway ring
    warm = make()
    warm.ingest(parts[1])
    warm.advance()
    jax.block_until_ready(warm.query(lq, uq, 0, warm.epoch).answer)

    w = make()
    ing_times, adv_times = [], []
    for part in parts[1:]:
        t0 = time.perf_counter()
        w.ingest(part)
        ing_times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        w.advance()
        adv_times.append(time.perf_counter() - t0)
    dt = float(np.median(ing_times))
    record("updates.window.ingest", dt / per * 1e6,
           f"recs_per_s={per / dt:.0f}")
    record("updates.window.advance", float(np.median(adv_times)) * 1e6,
           f"rows={per}")

    # full retained window: the fused multi-level execution over every
    # sealed epoch the ring still holds
    t, _ = time_fn(lambda l, u: w.query(l, u, w.oldest, w.epoch), lq, uq)
    record("updates.window.query_full", t / nq * 1e6,
           f"epochs={w.epoch - w.oldest + 1}")
    # single sealed epoch: one level, the sliding-window steady state
    t, _ = time_fn(lambda l, u: w.query(l, u, w.epoch - 1, w.epoch - 1),
                   lq, uq)
    record("updates.window.query_epoch", t / nq * 1e6)

    _emit_json(results, {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n": n, "nq": nq, "capacity": capacity, "window": ring,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }, out_path)
    return rows


def run_lsm(n=100_000, nq=2048, capacity=2048, dim=1, backends=("xla",),
            out_path=None):
    """LSM ladder sweep (``--lsm``): **worst-case** (max, not median)
    per-op latencies — the logarithmic method's whole point is the
    guarantee on the worst single update, so these metrics aggregate with
    ``max`` and check_regression gates them against the max envelope.

    Per backend: worst insert op (buffered append, possibly carrying a
    synchronous bounded level-compaction), worst tombstone delete op,
    worst compaction-carrying op alone, worst extremal (victim-shadow)
    delete — which must never compact — and the fused multi-level query
    latency over the final ladder.  Metric names carry the
    ``updates.lsm.`` / ``updates2d.lsm.`` prefix; the record's meta
    carries ``lsm=1`` and the final deterministic ``levels`` count so
    check_regression pairs it only with LSM baselines of the same ladder
    shape."""
    from repro.data import make_queries_1d, make_queries_2d
    from repro.engine import LsmEngine, LsmEngine2D

    rows = []
    results = []

    def record(name, value, derived=""):
        rows.append(row(name, value, derived))
        results.append({"name": name, "us_per_query": value,
                        "derived": derived})

    rng = np.random.default_rng(0x15B)
    batch = capacity // 4
    n_batches = 10
    prefix = "updates.lsm" if dim == 1 else "updates2d.lsm"

    if dim == 1:
        keys, _ = dataset("tweet", n)
        q = tuple(map(jnp.asarray, make_queries_1d(keys, nq)))
        lo, hi = float(keys.min()), float(keys.max())

        def make(backend):
            return LsmEngine(keys, agg="count", delta=50.0, backend=backend,
                             capacity=capacity, background=False)

        def ins_batch(m):
            return (rng.uniform(lo, hi, m),)

        del_batches = [(keys[i * batch: i * batch + batch // 2].copy(),)
                       for i in (1, 3, 5)]
    else:
        px, py = dataset("osm", n)
        w = 50.0 + 20.0 * np.sin(px / 7.0) + 15.0 * np.cos(py / 11.0)
        q = tuple(map(jnp.asarray, make_queries_2d(px, py, nq)))
        delta = 0.01 * float(np.abs(w).sum())
        x0, x1 = float(px.min()), float(px.max())
        y0, y1 = float(py.min()), float(py.max())

        def make(backend):
            return LsmEngine2D(px, py, w, agg="sum2d", delta=delta,
                               backend=backend, capacity=capacity,
                               max_depth=8, background=False)

        def ins_batch(m):
            return (rng.uniform(x0, x1, m), rng.uniform(y0, y1, m),
                    rng.uniform(0, 100, m))

        del_batches = [(px[i * batch: i * batch + batch // 2].copy(),
                        py[i * batch: i * batch + batch // 2].copy())
                       for i in (1, 3, 5)]

    levels = None
    for backend in backends:
        # warm the per-shape append/delete/query compiles on a throwaway
        # engine so one-off traces never land on the timed worst case
        warm = make(backend)
        warm.insert(*ins_batch(batch))
        warm.delete(*tuple(c[: batch // 2] for c in del_batches[0]))
        jax.block_until_ready(warm.query(*q).answer)

        eng = make(backend)
        ins_worst = comp_worst = 0.0
        compactions = 0
        for _ in range(n_batches):
            c0 = eng.compaction_count
            cols = ins_batch(batch)
            t0 = time.perf_counter()
            eng.insert(*cols)
            dt = (time.perf_counter() - t0) * 1e6
            ins_worst = max(ins_worst, dt)
            if eng.compaction_count > c0:   # op carried a level-compaction
                comp_worst = max(comp_worst, dt)
                compactions += 1
        record(f"{prefix}.insert_worst.{backend}", ins_worst,
               f"batch={batch};levels={eng.n_levels}")
        record(f"{prefix}.compact_worst.{backend}", comp_worst,
               f"compactions={compactions}")

        del_worst = 0.0
        for cols in del_batches:
            t0 = time.perf_counter()
            eng.delete(*cols)
            del_worst = max(del_worst, (time.perf_counter() - t0) * 1e6)
        record(f"{prefix}.delete_worst.{backend}", del_worst,
               f"batch={batch // 2}")

        t, _ = time_fn(lambda *r: eng.query(*r), *q)
        record(f"{prefix}.query_multilevel.{backend}", t / nq * 1e6,
               f"levels={eng.n_levels}")
        levels = eng.n_levels

        if dim == 1:
            # extremal victim-shadow deletes: the headline guarantee —
            # deleting a maximum NEVER triggers a merge on the write path
            vals = 50.0 + 20.0 * np.sin(np.asarray(keys) / 3.0)
            meng = LsmEngine(keys, vals, agg="max", delta=50.0,
                             backend=backend, capacity=capacity,
                             background=False)
            meng.delete(keys[:1].copy())          # warm the shadow rebuild
            ext_worst = 0.0
            for i in range(1, 9):
                t0 = time.perf_counter()
                meng.delete(keys[i * 17: i * 17 + 1].copy())
                ext_worst = max(ext_worst,
                                (time.perf_counter() - t0) * 1e6)
            assert meng.compaction_count == 0, "extremal delete compacted"
            record(f"{prefix}.extremal_delete_worst.{backend}", ext_worst,
                   "no_merge=1")

    meta = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n": n, "nq": nq, "capacity": capacity, "lsm": 1,
        "levels": levels,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }
    if dim == 2:
        meta["dim"] = 2
    _emit_json(results, meta, out_path)
    return rows


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="small shapes for CI smoke runs")
    p.add_argument("--dim", type=int, default=1, choices=(1, 2),
                   help="1: DynamicEngine on TWEET (default); 2: "
                        "DynamicEngine2D sum2d on OSM (selective refit)")
    p.add_argument("--window", action="store_true",
                   help="bench the epoch-ring window engine instead of the "
                        "flat delta-buffered engine: ingest/advance "
                        "latency + windowed query latency "
                        "(updates.window.* metric families)")
    p.add_argument("--lsm", action="store_true",
                   help="bench the LSM level ladder instead of the flat "
                        "delta-buffered engine: worst-case (max) per-op "
                        "insert/delete/compaction latency + multi-level "
                        "query latency (updates*.lsm.* metric families)")
    p.add_argument("--out", default=None,
                   help="write the JSON record here instead of the "
                        "committed BENCH_updates.json")
    args = p.parse_args()
    if args.window:
        if args.tiny:
            run_window(n=12_000, nq=1024, capacity=2048, out_path=args.out)
        else:
            run_window(out_path=args.out)
    elif args.lsm:
        if args.tiny:
            shapes = (dict(n=30_000, nq=1024, capacity=1024) if args.dim == 1
                      else dict(n=8_000, nq=512, capacity=512))
        else:
            shapes = (dict() if args.dim == 1
                      else dict(n=40_000, nq=1024, capacity=1024))
        run_lsm(dim=args.dim, out_path=args.out, **shapes)
    elif args.dim == 2:
        if args.tiny:
            run2d(n=8_000, nq=512, capacity=512, out_path=args.out)
        else:
            run2d(out_path=args.out)
    elif args.tiny:
        run(n=30_000, nq=1024, capacity=1024, out_path=args.out)
    else:
        run(out_path=args.out)


if __name__ == "__main__":
    main()
