"""Roofline aggregation: reads benchmarks/results/dryrun/*.json and emits
the §Dry-run and §Roofline markdown tables for EXPERIMENTS.md.

MODEL_FLOPS convention (per device): c * N_active * tokens_per_device,
c = 6 for training (fwd+bwd), 2 for inference; N_active counts non-expert
params plus the top_k/E fraction of expert params.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single] > table.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

# TPU v5e roofline constants (match launch/dryrun.py)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def param_counts():
    import jax
    from repro.configs import ARCHS
    from repro.models import init_model

    out = {}
    for name, cfg in ARCHS.items():
        abs_p = jax.eval_shape(lambda c=cfg: init_model(jax.random.PRNGKey(0), c))
        total = expert = 0
        def walk(path, tree):
            nonlocal total, expert
            if hasattr(tree, "items"):
                for k, v in tree.items():
                    walk(path + "/" + k, v)
            else:
                n = int(np.prod(tree.shape))
                total += n
                if "/moe/w" in path:
                    expert += n
        walk("", abs_p)
        frac = (cfg.top_k / cfg.n_experts) if cfg.n_experts else 0.0
        active = total - expert + expert * frac
        out[name] = (total, active)
    return out


def model_flops(rec, counts):
    from repro.configs import ARCHS, SHAPES
    cfg = ARCHS[rec["arch"]]
    shape = SHAPES[rec["shape"]]
    total, active = counts[rec["arch"]]
    chips = rec["chips"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        c = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * (shape.seq_len if cfg.family != "encdec"
                                       else shape.seq_len + cfg.dec_seq)
        c = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        c = 2.0
    return c * active * tokens / chips


def analytic_terms(rec, counts):
    """First-principles roofline terms (per device, per step).

    Needed because XLA ``cost_analysis`` counts while-loop bodies once: with
    layer scans (L iters), microbatch scans (M) and attention-chunk scans,
    HLO-derived train-cell terms are under-counted by those trip factors
    (observed MODEL/HLO ratios of 80-250x).  Model:

    compute: c*N_active*tokens/chips, c = 8 train (6 fwd+bwd + ~2 remat
             forward recompute), 2 inference; + attention score flops
             12*L*S*min(S,window)*d_head*heads per token batch (train).
    memory:  param traffic (FSDP: full weights streamed per microbatch) +
             optimizer state r/w (train) + activation r/w (~24*d bytes per
             token-layer) + KV-cache read (decode).
    collective: FSDP all-gather (params * (dp-1)/dp per microbatch) +
             gradient reduce-scatter + TP activation all-reduces
             (2 per layer * token bytes), per device.
    """
    from repro.configs import ARCHS, SHAPES
    cfg = ARCHS[rec["arch"]]
    shape = SHAPES[rec["shape"]]
    chips = rec["chips"]
    tp = 16
    dp = chips // tp
    total, active = counts[rec["arch"]]
    P4 = total * 4.0                       # fp32 master params
    L = cfg.n_layers + cfg.n_dec_layers
    d = cfg.d_model
    kind = shape.kind
    B, S = shape.global_batch, shape.seq_len
    if kind == "train":
        tokens = B * (S if cfg.family != "encdec" else S + cfg.dec_seq)
        M = rec.get("microbatches", 16)
        flops = 8.0 * active * tokens / chips
        if cfg.n_heads:
            w = min(S, cfg.window or S)
            flops += 3 * 4.0 * tokens * L * w * cfg.head_dim * cfg.n_heads / chips
        # per device: params TP-sharded (1/tp) streamed (gathered) per
        # microbatch + opt-state r/w + activation traffic
        mem = (M * P4 / tp + 8 * P4 / chips) \
            + tokens * L * d * 24.0 * 2 / chips
        coll = (M * P4 / tp * (dp - 1) / dp + P4 / tp) \
            + M * 2 * L * (tokens / chips) * d * 2.0 * 2
    elif kind == "prefill":
        tokens = B * S
        flops = 2.0 * active * tokens / chips
        if cfg.n_heads:
            w = min(S, cfg.window or S)
            flops += 4.0 * tokens * L * w * cfg.head_dim * cfg.n_heads / chips
        mem = P4 / tp / 2 + tokens * L * d * 12.0 / chips   # bf16 weights
        coll = (P4 / tp / 2 * (dp - 1) / dp) \
            + 2 * L * (tokens / chips) * d * 2.0
    else:  # decode: weights stay resident (TP-sharded); no FSDP gather
        tokens = B
        flops = 2.0 * active * tokens / chips
        kv_local = 0.0
        if cfg.n_kv_heads:
            kv_local = (2 * L * B * min(S, cfg.window or S)
                        * cfg.n_kv_heads * cfg.head_dim * 2.0) / chips
        mem = P4 / 2 / chips + kv_local     # bf16 weight read + local KV
        coll = 2 * L * tokens * d * 2.0 * 2 / tp
    return {"compute_s": flops / PEAK_FLOPS, "memory_s": mem / HBM_BW,
            "collective_s": coll / ICI_BW}


def load(mesh: str):
    recs = []
    for p in sorted(glob.glob(os.path.join(RESULTS, f"{mesh}_*.json"))):
        recs.append(json.load(open(p)))
    return recs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args(argv)
    counts = param_counts()
    recs = load(args.mesh)
    print("NOTE: cmp/mem/coll(H) are HLO-derived (cost_analysis + collective "
          "parse) and UNDER-count scan trip counts; cmp/mem/coll(A) are the "
          "analytic model (benchmarks/roofline.py) — dominant term and the "
          "roofline fraction are taken from (A).")
    print("| arch | shape | status | mem/dev GB | cmp(H) | mem(H) | coll(H) "
          "| cmp(A) | mem(A) | coll(A) | dominant(A) | frac | note |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "skipped":
            arch, shape = r["cell"].split("_", 2)[1:]
            print(f"| {arch} | {shape} | SKIP |" + " - |" * 9 +
                  f" {r['reason'][:58]} |")
            continue
        if r["status"] == "error":
            arch, shape = r["cell"].split("_", 2)[1:]
            print(f"| {arch} | {shape} | ERROR |" + " - |" * 9 +
                  f" {r['error'][:58]} |")
            continue
        t = r["roofline_terms_s"]
        a = analytic_terms(r, counts)
        dom = max(a, key=a.get)
        # roofline fraction: useful compute time / total modeled step time
        frac = a["compute_s"] / max(sum(a.values()), 1e-30)
        note = {
            "compute_s": "MXU-bound: raise per-chip batch / cut remat",
            "memory_s": "HBM-bound: stream weights less / fuse / cast",
            "collective_s": "ICI-bound: reshard or overlap gathers",
        }[dom]
        print(f"| {r['arch']} | {r['shape']} | ok | "
              f"{r['memory']['per_device_total']/1e9:.2f} | "
              f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | "
              f"{t['collective_s']:.2e} | "
              f"{a['compute_s']:.2e} | {a['memory_s']:.2e} | "
              f"{a['collective_s']:.2e} | {dom.replace('_s','')} | "
              f"{frac:.2f} | {note} |")


if __name__ == "__main__":
    main()
