"""Serving-engine benchmark: open-loop Poisson load against the
continuous-batching ``ServingEngine`` (DESIGN.md §13).

Three phases over one request stream of mixed kinds (1-D COUNT/SUM on
TWEET/HKI, 2-D COUNT/dominance-MAX on OSM), each request a small batch
of 1..8 queries:

* **cold** — a fresh engine with an empty AOT cache: every first
  (table, bucket) dispatch traces + compiles on the serving path, and
  open-loop arrivals keep coming while it does, so head-of-line blocking
  lands in the recorded latency exactly as it would in production;
* **warm** — the same stream after ``warmup()`` compiled the full bucket
  ladder: steady-state serving, zero traces (asserted on engine stats);
* **mixed** — the warm stream again with a concurrent open-loop writer
  staging async inserts (``wait=False``): measures that the staged
  update pipeline keeps writes off the read path (reader p99 within 2x
  of the read-only p99 is the acceptance bound).

Latency is completion - *scheduled arrival* (queue wait included; the
future resolves device-ready).  Sustained QPS is recorded inverted, as
microseconds per request, so the regression gate's lower-is-better
envelope applies; the raw QPS rides in ``derived``.  Appends one
timestamped record to ``BENCH_serve.json`` at the repo root (same
history format as ``BENCH_engine.json``).

Writers total fewer records than the delta-buffer capacity so no merge
(plan swap -> AOT recompile) lands inside the timed window; plan-swap
behaviour is covered by tests/test_serve.py.
"""
from __future__ import annotations

import argparse
import pathlib
import platform
import threading
import time

import jax
import numpy as np

from .common import emit_history, row

_BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"


def _build_session(n1, n2, capacity, backend):
    from repro.api import ErrorBudget, PolyFit, TableSpec
    from repro.data import hki_series, osm_points, tweet_latitudes

    lat = tweet_latitudes(n1)
    ts, vals = hki_series(n1)
    px, py = osm_points(n2)
    pw = 50.0 + 20.0 * np.sin(px / 7.0) + 15.0 * np.cos(py / 11.0)
    # auto_refit off: merges (and their AOT recompiles) stay out of the
    # timed phases — the writer volume is capped below capacity anyway
    kw = dict(dynamic=True, capacity=capacity, background=True,
              auto_refit=False)
    session = PolyFit.fit(
        {"count": lat, "sum": (ts, vals), "count2d": (px, py),
         "max2d": (px, py, pw)},
        {"count": TableSpec("count", ErrorBudget(abs=100.0, rel=0.01),
                            deg=2, **kw),
         "sum": TableSpec("sum", ErrorBudget(
             abs=100.0 * float(np.abs(vals).mean()), rel=0.01), deg=2,
             **kw),
         "count2d": TableSpec("count2d", ErrorBudget(abs=100.0, rel=0.01),
                              deg=3, **kw),
         "max2d": TableSpec("max2d", ErrorBudget(
             abs=0.1 * float(pw.max() - pw.min()), rel=0.01), deg=3,
             **kw)},
        backend=backend)
    domains = {
        "count": (float(lat.min()), float(lat.max())),
        "sum": (float(ts.min()), float(ts.max())),
        "count2d": (float(px.min()), float(px.max()),
                    float(py.min()), float(py.max())),
    }
    return session, domains


def _make_stream(domains, nreq, seed):
    """A reproducible mixed-kind request stream (list of QuerySpec)."""
    from repro.api import QuerySpec

    rng = np.random.default_rng(seed)
    kinds = ("count", "sum", "count2d", "max2d")
    stream = []
    for _ in range(nreq):
        kind = kinds[int(rng.integers(len(kinds)))]
        m = int(rng.integers(1, 9))
        if kind in ("count", "sum"):
            a, b = domains[kind]
            lq = rng.uniform(a, b, m)
            uq = lq + rng.uniform(0, (b - a) / 4, m)
            stream.append(QuerySpec.range(kind, lq, uq))
        elif kind == "count2d":
            x0, x1, y0, y1 = domains["count2d"]
            lx = rng.uniform(x0, x1, m)
            ly = rng.uniform(y0, y1, m)
            stream.append(QuerySpec.rect(
                kind, lx, lx + rng.uniform(0, (x1 - x0) / 4, m),
                ly, ly + rng.uniform(0, (y1 - y0) / 4, m)))
        else:
            x0, x1, y0, y1 = domains["count2d"]
            stream.append(QuerySpec.corner(kind, rng.uniform(x0, x1, m),
                                           rng.uniform(y0, y1, m)))
    return stream


def _open_loop(engine, stream, rate, seed):
    """Replay the stream at Poisson rate ``rate`` req/s; per-request
    latency is completion (future resolved, device-ready) minus the
    *scheduled* arrival, so queue wait and head-of-line blocking count.
    Returns (latencies_seconds, wall_seconds)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, len(stream)))
    lats = [0.0] * len(stream)
    futures = []
    t0 = time.perf_counter()

    def _done_cb(i, at):
        def cb(_fut):
            lats[i] = (time.perf_counter() - t0) - at
        return cb

    for i, (spec, at) in enumerate(zip(stream, arrivals)):
        delay = at - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        fut = engine.submit(spec)
        fut.add_done_callback(_done_cb(i, at))
        futures.append(fut)
    for fut in futures:
        fut.result()
    return np.array(lats), time.perf_counter() - t0


def _writer_loop(engine, domains, *, chunks, chunk, rate, seed, stage_us):
    """Open-loop async writer: stages ``chunks`` insert chunks at Poisson
    rate (``wait=False`` — never blocks on the device)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, chunks))
    t0 = time.perf_counter()
    for i in range(chunks):
        delay = arrivals[i] - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        kind = ("count", "sum")[i % 2]
        a, b = domains[kind]
        keys = rng.uniform(a, b, chunk)
        t1 = time.perf_counter()
        if kind == "count":
            engine.insert(kind, keys, wait=False)
        else:
            engine.insert(kind, keys, rng.uniform(0, 10, chunk),
                          wait=False)
        stage_us.append((time.perf_counter() - t1) * 1e6 / chunk)


def _open_loop_chaos(engine, stream, rate, seed, crash_exc, timeout=120.0):
    """Open-loop replay under failure injection.  A request failed by an
    injected worker crash is resubmitted once (the client-side retry a
    real deployment performs); latency runs from the *original* scheduled
    arrival through the resubmission.  Returns
    (latencies_of_ok, ok, failed, stranded, client_retries) — a future
    that neither resolves nor raises within ``timeout`` is *stranded*,
    the invariant the chaos gate holds at zero."""
    rng = np.random.default_rng(seed)
    n = len(stream)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    done_at = [0.0] * n
    outcome: list = [None] * n
    events = [threading.Event() for _ in range(n)]
    retries = [0]
    t0 = time.perf_counter()

    def _submit(i, attempt):
        def cb(fut):
            exc = fut.exception()
            if isinstance(exc, crash_exc) and attempt == 0:
                # resubmit promptly (from the resolving thread), so the
                # retried request's latency reflects restart time — not
                # how long the harness took to notice
                retries[0] += 1
                try:
                    _submit(i, 1)
                    return
                except Exception as e:    # engine refused the resubmit
                    exc = e
            done_at[i] = time.perf_counter() - t0
            outcome[i] = exc
            events[i].set()
        engine.submit(stream[i]).add_done_callback(cb)

    for i, at in enumerate(arrivals):
        delay = at - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        _submit(i, 0)

    ok = failed = stranded = 0
    lats = []
    deadline = time.monotonic() + timeout
    for i, ev in enumerate(events):
        if not ev.wait(max(0.0, deadline - time.monotonic())):
            stranded += 1
        elif outcome[i] is None:
            ok += 1
            lats.append(done_at[i] - arrivals[i])
        else:
            failed += 1
    return np.array(lats), ok, failed, stranded, retries[0]


def run_chaos(n1=150_000, n2=60_000, nreq=600, rate=150.0, capacity=2048,
              backend="xla", max_bucket=256, out_path=None, seed=0xC405):
    """Chaos mode (``--chaos``): the warm open-loop stream under a crash
    storm — a worker crash every ~25 admission batches plus 3% transient
    dispatch failures (retried in-engine with backoff) — then a one-shot
    crash to time recovery-to-warm.

    Availability (requests answered within one client retry) and stranded
    futures are *hard-asserted* here (>=99%, ==0): they sit at/near their
    ideal values, so a ratio gate over them is meaningless — the
    regression gate instead tracks the continuous tail metrics this
    emits, ``serve.chaos.p99`` and ``serve.chaos.recovery``."""
    from repro.dist.fault_tolerance import (FailureInjector, RetryPolicy,
                                            SimulatedPodFailure)
    from repro.serve import ServingEngine

    rows, results = [], []

    def record(name, value, derived=""):
        rows.append(row(name, value, derived))
        results.append({"name": name, "us_per_query": value,
                        "derived": derived})

    session, domains = _build_session(n1, n2, capacity, backend)
    stream = _make_stream(domains, nreq, seed)

    # -- phase 1: crash storm over the warm stream ------------------------
    # the p-trigger rng is seeded (replayable), so the transient-failure
    # count is deterministic per shape; p=0.03 guarantees the in-engine
    # retry path actually exercises at the tiny shape's ~120 dispatches
    inj = FailureInjector(seed=seed).arm("serve.worker", nth=25)
    inj.arm("serve.dispatch", p=0.03)
    pol = RetryPolicy(max_attempts=4, base=0.002, cap=0.02,
                      retry_on=(SimulatedPodFailure,))
    eng = ServingEngine(session, max_queue=max(2 * nreq, 64),
                        max_batch=max_bucket, workers=2, injector=inj,
                        retry=pol)
    eng.warmup(max_bucket=max_bucket)
    lats, ok, failed, stranded, retries = _open_loop_chaos(
        eng, stream, rate, seed + 1, SimulatedPodFailure)
    time.sleep(0.1)                 # let the supervisor catch the last crash
    st = eng.stats
    health = eng.health()
    eng.shutdown()
    avail = ok / len(stream)
    assert stranded == 0, f"{stranded} futures stranded under crash storm"
    assert avail >= 0.99, f"availability {avail:.4f} < 0.99"
    assert st.worker_crashes >= 1, "crash storm never fired"
    assert st.restarts >= 1, "supervisor never restarted a worker"
    record("serve.chaos.p99", float(np.percentile(lats, 99)) * 1e6,
           f"avail={avail:.4f};crashes={st.worker_crashes};"
           f"restarts={st.restarts};client_retries={retries};"
           f"dispatch_retries={pol.retries};failed={failed}")

    # -- phase 2: recovery-to-warm after a one-shot crash -----------------
    # median of several crash->first-answer cycles: a single cycle rides
    # the supervisor poll + worker queue-wait phase, which jitters ~2x —
    # too wide for the gate's envelope on one sample
    inj2 = FailureInjector()
    eng2 = ServingEngine(session, injector=inj2)
    eng2.warmup(max_bucket=max_bucket)
    spec = stream[0]
    cycles = []
    for _ in range(5):
        inj2.arm("serve.worker", nth=1, times=1)    # resets site counters
        t_crash = time.perf_counter()
        try:
            eng2.submit(spec).result(timeout=60)
            raise AssertionError("one-shot injected crash did not fire")
        except SimulatedPodFailure:
            pass
        # the next request queues until the supervisor's replacement
        # worker picks it up: its completion time *is* recovery-to-warm
        eng2.submit(spec).result(timeout=60)
        cycles.append(time.perf_counter() - t_crash)
    assert eng2.health()["workers_alive"] == 1
    recovery = float(np.median(cycles))
    eng2.shutdown()
    record("serve.chaos.recovery", recovery * 1e6,
           f"restarts={eng2.stats.restarts};"
           f"cycle_max_us={max(cycles) * 1e6:.0f};"
           f"storm_workers_alive={health['workers_alive']}")

    emit_history(results, {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n1": n1, "n2": n2, "nreq": nreq, "rate": rate,
        "capacity": capacity, "backend": backend, "chaos": True,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }, out_path or _BENCH_JSON, "bench_serve")
    return rows


def run(n1=150_000, n2=60_000, nreq=400, rate=200.0, capacity=2048,
        backend="xla", max_bucket=256, out_path=None, seed=0x5E12):
    from repro.serve import ServingEngine

    rows, results = [], []

    def record(name, value, derived=""):
        rows.append(row(name, value, derived))
        results.append({"name": name, "us_per_query": value,
                        "derived": derived})

    session, domains = _build_session(n1, n2, capacity, backend)
    stream = _make_stream(domains, nreq, seed)

    # -- phase 1: cold-trace serving (empty AOT cache) --------------------
    cold = ServingEngine(session, max_queue=max(2 * nreq, 64),
                         max_batch=max_bucket)
    lat_cold, _ = _open_loop(cold, stream, rate, seed + 1)
    cold.shutdown()
    record("serve.cold.p50", float(np.percentile(lat_cold, 50)) * 1e6,
           f"compiles={cold.stats.aot_compiles}")
    record("serve.cold.p99", float(np.percentile(lat_cold, 99)) * 1e6)

    # -- phase 2: warm AOT ladder, read-only steady state -----------------
    warm = ServingEngine(session, max_queue=max(2 * nreq, 64),
                         max_batch=max_bucket)
    n_exec = warm.warmup(max_bucket=max_bucket)
    c0 = warm.stats.aot_compiles
    lat_warm, wall = _open_loop(warm, stream, rate, seed + 1)
    traced = warm.stats.aot_compiles - c0
    assert traced == 0, f"warm phase compiled {traced} executables"
    p50c = float(np.percentile(lat_cold, 50)) * 1e6
    p50w = float(np.percentile(lat_warm, 50)) * 1e6
    p99w = float(np.percentile(lat_warm, 99)) * 1e6
    record("serve.warm.p50", p50w,
           f"ladder={n_exec};speedup_vs_cold={p50c / p50w:.1f}x")
    record("serve.warm.p99", p99w)
    record("serve.qps", wall / nreq * 1e6,
           f"qps={nreq / wall:.0f};coalesced={warm.stats.coalesced}")

    # -- phase 3: same read stream + concurrent async writers -------------
    chunk = 32
    chunks = min(capacity // (2 * chunk), max(8, int(rate / 8)))
    stage_us: list = []
    wt = threading.Thread(
        target=_writer_loop, args=(warm, domains),
        kwargs=dict(chunks=chunks, chunk=chunk, rate=rate / 16,
                    seed=seed + 2, stage_us=stage_us))
    wt.start()
    lat_mixed, _ = _open_loop(warm, stream, rate, seed + 3)
    wt.join()
    t0 = time.perf_counter()
    warm.drain_updates()
    drain_s = time.perf_counter() - t0
    p99m = float(np.percentile(lat_mixed, 99)) * 1e6
    record("serve.mixed.read_p50",
           float(np.percentile(lat_mixed, 50)) * 1e6,
           f"writes={chunks * chunk}")
    record("serve.mixed.read_p99", p99m,
           f"ratio_vs_readonly={p99m / p99w:.2f}x")
    if stage_us:
        record("serve.insert_stage", float(np.median(stage_us)),
               f"drain_s={drain_s:.3f}")
    warm.shutdown()

    emit_history(results, {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n1": n1, "n2": n2, "nreq": nreq, "rate": rate,
        "capacity": capacity, "backend": backend,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }, out_path or _BENCH_JSON, "bench_serve")
    return rows


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="small shapes for CI smoke runs")
    p.add_argument("--chaos", action="store_true",
                   help="failure-injection mode: crash storm + "
                        "recovery-to-warm (serve.chaos.* metrics)")
    p.add_argument("--backend", default="xla")
    p.add_argument("--out", default=None,
                   help="write the JSON record here instead of the "
                        "committed BENCH_serve.json")
    args = p.parse_args()
    if args.chaos:
        # nreq/rate differ from the non-chaos tiny shape on purpose: the
        # regression gate pairs records by meta, so chaos candidates only
        # ever compare against committed chaos baselines
        if args.tiny:
            run_chaos(n1=30_000, n2=8_000, nreq=120, rate=30.0,
                      capacity=1024, backend=args.backend,
                      out_path=args.out)
        else:
            run_chaos(backend=args.backend, out_path=args.out)
    elif args.tiny:
        # rate is deliberately below the single-core dispatch capacity
        # (~50 req/s on CI-class CPUs): an open-loop gate in the
        # saturated regime amplifies runner-speed noise nonlinearly,
        # which a 2x envelope cannot absorb
        run(n1=30_000, n2=8_000, nreq=150, rate=25.0, capacity=1024,
            backend=args.backend, out_path=args.out)
    else:
        run(backend=args.backend, out_path=args.out)


if __name__ == "__main__":
    main()
