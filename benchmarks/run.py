"""Benchmark entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (see DESIGN.md §8 for the mapping).

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller datasets (CI-scale)")
    args = ap.parse_args()

    from . import bench_table5, bench_construction, bench_sweeps, bench_kernels

    print("name,us_per_call,derived")
    if args.quick:
        bench_table5.run(n1=50_000, n2=30_000)
        bench_construction.run(sizes=(20_000, 50_000))
        bench_kernels.run(n=50_000)
    else:
        bench_table5.run()
        bench_construction.run()
        bench_sweeps.run()
        bench_kernels.run()


if __name__ == '__main__':
    main()
