"""Shared benchmark utilities: datasets, query workloads, timing,
perf-trajectory JSON history."""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax


def emit_history(results, meta, path, label: str) -> None:
    """Append one timestamped {"meta", "results"} record to a BENCH-style
    JSON history file (BENCH_engine.json / BENCH_updates.json)."""
    path = pathlib.Path(path)
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError) as e:
            # never silently reset the cross-PR trajectory: keep the broken
            # file next to the fresh one so the history can be recovered
            backup = path.with_suffix(path.suffix + ".corrupt")
            print(f"[{label}] WARNING: {path} unreadable ({e}); saving the "
                  f"broken file to {backup} and starting a fresh history")
            try:
                backup.write_bytes(path.read_bytes())
            except OSError:
                pass
            history = []
    history.append({"meta": meta, "results": results})
    path.write_text(json.dumps(history, indent=2) + "\n")
    print(f"[{label}] wrote {path} ({len(history)} records)")

# Build the paper's three workloads once per process (cached).
_CACHE = {}


def dataset(name: str, n: int):
    from repro.data import hki_series, osm_points, tweet_latitudes
    key = (name, n)
    if key not in _CACHE:
        if name == "hki":
            t, v = hki_series(n)
            _CACHE[key] = (t, v)
        elif name == "tweet":
            lat = tweet_latitudes(n)
            _CACHE[key] = (lat, np.ones_like(lat))
        elif name == "osm":
            _CACHE[key] = osm_points(n)
        else:
            raise KeyError(name)
    return _CACHE[key]


def time_fn(fn, *args, warmup: int = 2, iters: int = 5):
    """Median wall time of a jitted callable (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def row(name: str, us_per_call: float, derived: str = ""):
    line = f"{name},{us_per_call:.3f},{derived}"
    print(line)
    return line
