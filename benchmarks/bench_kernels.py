"""Kernel- and engine-level benchmark (beyond paper): Pallas (interpret) vs
XLA ref at the raw-kernel layer, the engine backend sweep (xla vs
pallas-interpret vs ref, fused Q_rel refinement included), and the analytic
TPU roofline of the fused range_sum kernel.

Arithmetic intensity of range_sum per query block against H segments:
compare-all + one-hot matmul reads the (H, deg+3) table once per query
block and performs ~2*BQ*H*(deg+5) FLOPs on it, so intensity grows with BQ
— the kernel is compute-bound on the MXU for BQ >= ~64 at f32.

The engine sweep appends its per-query timings to ``BENCH_engine.json`` at
the repo root so the perf trajectory is recorded across PRs.
"""
from __future__ import annotations

import argparse
import functools
import pathlib
import platform
import time

import jax
import jax.numpy as jnp

from .common import dataset, emit_history, row, time_fn

PEAK_FLOPS = 197e12
HBM_BW = 819e9

_BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_engine.json"


def _emit_engine_json(results, meta, out_path=None):
    """Append one timestamped record per run (the perf trajectory file)."""
    emit_history(results, meta, out_path or _BENCH_JSON, "bench_kernels")


# CI smoke shape: must match a committed BENCH_engine.json record's meta so
# check_regression.py can pair the fresh run with its baseline
TINY = dict(n=30_000, nq=1024, n2=10_000, nq2=256)


def run(n=200_000, nq=4096, n2=40_000, nq2=1024, eps_rel=0.01,
        out_path=None):
    from repro.core import build_index_1d, build_index_2d
    from repro.data import make_queries_1d, make_queries_2d
    from repro.engine import BACKENDS, Engine, build_plan, build_plan_2d
    from repro.kernels import from_index, range_max, range_sum

    rows = []
    keys, _ = dataset("tweet", n)
    lq, uq = map(jnp.asarray, make_queries_1d(keys, nq))
    pf = build_index_1d(keys, None, "count", deg=2, delta=50.0)
    tbl = from_index(pf, dtype=jnp.float32)
    for backend in ("ref", "pallas"):
        f = functools.partial(range_sum, tbl, backend=backend)
        t, _ = time_fn(f, lq, uq)
        rows.append(row(f"kernels.range_sum.{backend}", t / nq * 1e6,
                        f"Hpad={tbl.seg_lo.shape[0]}"))
    tk, vals = dataset("hki", n)
    pfm = build_index_1d(tk, vals, "max", deg=3, delta=100.0)
    tblm = from_index(pfm, dtype=jnp.float32)
    l2, u2 = map(jnp.asarray, make_queries_1d(tk, nq))
    for backend in ("ref", "pallas"):
        f = functools.partial(range_max, tblm, backend=backend)
        t, _ = time_fn(f, l2, u2)
        rows.append(row(f"kernels.range_max.{backend}", t / nq * 1e6,
                        f"Hpad={tblm.seg_lo.shape[0]}"))

    # ---------------- engine backend sweep (fused Q_rel included) --------
    plan = build_plan(pf)
    planm = build_plan(pfm)
    px, py = dataset("osm", n2)
    pf2 = build_index_2d(px, py, deg=3, delta=50.0)
    plan2 = build_plan_2d(pf2)
    q2 = tuple(map(jnp.asarray, make_queries_2d(px, py, nq2)))
    engine_results = []

    def record(name, t, per, derived=""):
        rows.append(row(name, t / per * 1e6, derived))
        engine_results.append({"name": name, "us_per_query": t / per * 1e6,
                               "derived": derived})

    for b in BACKENDS:
        eng = Engine(backend=b)
        t, _ = time_fn(lambda l, u: eng.sum(plan, l, u), lq, uq)
        record(f"engine.sum.{b}.Qabs", t, nq, f"Hpad={plan.seg_lo.shape[0]}")
        t, _ = time_fn(lambda l, u: eng.sum(plan, l, u, eps_rel=eps_rel),
                       lq, uq)
        record(f"engine.sum.{b}.Qrel", t, nq)
        t, _ = time_fn(lambda l, u: eng.extremum(planm, l, u), l2, u2)
        record(f"engine.max.{b}.Qabs", t, nq,
               f"Hpad={planm.seg_lo.shape[0]}")
        t, _ = time_fn(lambda a, c, d, e: eng.count2d(plan2, a, c, d, e), *q2)
        record(f"engine.count2d.{b}.Qabs", t, nq2,
               f"Lpad={plan2.leaf_mx0.shape[0]}")

    _emit_engine_json(engine_results, {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n": n, "nq": nq, "n2": n2, "nq2": nq2,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }, out_path)

    # analytic roofline of the fused range_sum kernel on TPU v5e (f32)
    BQ, deg = 256, 2
    H = int(tbl.seg_lo.shape[0])
    flops = 2 * BQ * H * (deg + 3 + 2) + BQ * H * 2     # matmul + compares
    bytes_moved = (H * (deg + 3 + 3) * 4                # table once / block
                   + BQ * 4 * 3)
    ai = flops / bytes_moved
    t_compute = flops / PEAK_FLOPS
    t_mem = bytes_moved / HBM_BW
    rows.append(row("kernels.range_sum.roofline_model",
                    max(t_compute, t_mem) / BQ * 1e6,
                    f"AI={ai:.1f}flop/B;bound={'compute' if t_compute > t_mem else 'memory'}"))
    return rows


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="small shapes for the CI benchmark-smoke job "
                        "(meta matches the committed baseline record)")
    p.add_argument("--out", default=None,
                   help="write the JSON record here instead of appending "
                        "to the committed BENCH_engine.json")
    args = p.parse_args()
    run(**TINY, out_path=args.out) if args.tiny else run(out_path=args.out)


if __name__ == "__main__":
    main()
