"""Kernel-level benchmark (beyond paper): Pallas (interpret) vs XLA ref,
plus the analytic TPU roofline of the fused range_sum kernel.

Arithmetic intensity of range_sum per query block against H segments:
compare-all + one-hot matmul reads the (H, deg+3) table once per query
block and performs ~2*BQ*H*(deg+5) FLOPs on it, so intensity grows with BQ
— the kernel is compute-bound on the MXU for BQ >= ~64 at f32.
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

from .common import dataset, row, time_fn

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def run(n=200_000, nq=4096):
    from repro.core import build_index_1d
    from repro.data import make_queries_1d
    from repro.kernels import from_index, range_max, range_sum

    rows = []
    keys, _ = dataset("tweet", n)
    lq, uq = map(jnp.asarray, make_queries_1d(keys, nq))
    pf = build_index_1d(keys, None, "count", deg=2, delta=50.0)
    tbl = from_index(pf, dtype=jnp.float32)
    for backend in ("ref", "pallas"):
        f = functools.partial(range_sum, tbl, backend=backend)
        t, _ = time_fn(f, lq, uq)
        rows.append(row(f"kernels.range_sum.{backend}", t / nq * 1e6,
                        f"Hpad={tbl.seg_lo.shape[0]}"))
    tk, vals = dataset("hki", n)
    pfm = build_index_1d(tk, vals, "max", deg=3, delta=100.0)
    tblm = from_index(pfm, dtype=jnp.float32)
    l2, u2 = map(jnp.asarray, make_queries_1d(tk, nq))
    for backend in ("ref", "pallas"):
        f = functools.partial(range_max, tblm, backend=backend)
        t, _ = time_fn(f, l2, u2)
        rows.append(row(f"kernels.range_max.{backend}", t / nq * 1e6,
                        f"Hpad={tblm.seg_lo.shape[0]}"))

    # analytic roofline of the fused range_sum kernel on TPU v5e (f32)
    BQ, deg = 256, 2
    H = int(tbl.seg_lo.shape[0])
    flops = 2 * BQ * H * (deg + 3 + 2) + BQ * H * 2     # matmul + compares
    bytes_moved = (H * (deg + 3 + 3) * 4                # table once / block
                   + BQ * 4 * 3)
    ai = flops / bytes_moved
    t_compute = flops / PEAK_FLOPS
    t_mem = bytes_moved / HBM_BW
    rows.append(row("kernels.range_sum.roofline_model",
                    max(t_compute, t_mem) / BQ * 1e6,
                    f"AI={ai:.1f}flop/B;bound={'compute' if t_compute > t_mem else 'memory'}"))
    return rows


if __name__ == "__main__":
    run()
