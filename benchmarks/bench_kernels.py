"""Kernel- and engine-level benchmark (beyond paper): Pallas (interpret) vs
XLA ref at the raw-kernel layer, the engine backend sweep (xla vs
pallas-interpret vs ref, fused Q_rel refinement included), and the analytic
TPU roofline of the fused range_sum kernel.

Arithmetic intensity of range_sum per query block against H segments:
compare-all + one-hot matmul reads the (H, deg+3) table once per query
block and performs ~2*BQ*H*(deg+5) FLOPs on it, so intensity grows with BQ
— the kernel is compute-bound on the MXU for BQ >= ~64 at f32.

The engine sweep appends its per-query timings to ``BENCH_engine.json`` at
the repo root so the perf trajectory is recorded across PRs.
"""
from __future__ import annotations

import argparse
import functools
import pathlib
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import dataset, emit_history, row, time_fn

PEAK_FLOPS = 197e12
HBM_BW = 819e9

_BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_engine.json"


def _emit_engine_json(results, meta, out_path=None):
    """Append one timestamped record per run (the perf trajectory file)."""
    emit_history(results, meta, out_path or _BENCH_JSON, "bench_kernels")


# CI smoke shape: must match a committed BENCH_engine.json record's meta so
# check_regression.py can pair the fresh run with its baseline
TINY = dict(n=30_000, nq=1024, n2=10_000, nq2=256,
            hs=(512, 2048), hs2=(1024, 4096), nqh=256)

# shard-sweep shape (the --shards mode); its record carries this meta so it
# pairs only with committed shard-sweep baselines
SHARD_SWEEP = dict(shard_h=4096, shard_nq=512, shard_s=(1, 2, 4, 8))

# quantile-inversion sweep shape (the --quantile mode): real fitted COUNT
# plans (keep_exact=True — synthetic plans carry no ref arrays and the
# kernel's key-grid snap needs them), one plan per delta so H sweeps the
# certificate granularity.  Meta carries n + nqh only, so the record pairs
# exclusively with committed quantile baselines
QUANTILE_SWEEP = dict(n=120_000, qn=512, deltas=(400.0, 100.0, 25.0))
QUANTILE_TINY = dict(n=30_000, qn=256, deltas=(200.0, 50.0))


def _synthetic_plan_1d(H: int, agg: str, deg: int, rng, dtype=jnp.float64):
    """Kernel-shaped IndexPlan with exactly H segments (no index build —
    fitting tens of thousands of segments would dominate the sweep)."""
    from repro.core.exact import build_sparse_table
    from repro.engine.plan import IndexPlan, big_sentinel, pad_to_multiple

    big = big_sentinel(dtype)
    edges = np.sort(rng.uniform(0.0, 1000.0, H + 1))
    seg_lo = jnp.asarray(edges[:-1], dtype)
    seg_hi = jnp.asarray(edges[1:], dtype)
    nxt = jnp.concatenate([seg_lo[1:], jnp.full((1,), big, dtype)])
    coeffs = jnp.asarray(rng.normal(0, 1, (H, deg + 1)), dtype)
    seg_agg = jnp.asarray(rng.normal(0, 1, H), dtype)
    st = jnp.asarray(build_sparse_table(np.asarray(seg_agg)))
    bh = min(512, H)
    return IndexPlan(
        agg=agg, deg=deg, delta=1.0, h=H, n=H, bh=bh,
        seg_lo=pad_to_multiple(seg_lo, bh, big),
        seg_next=pad_to_multiple(nxt, bh, big),
        seg_hi=pad_to_multiple(seg_hi, bh, big),
        coeffs=pad_to_multiple(coeffs, bh, 0.0),
        seg_agg=pad_to_multiple(seg_agg, bh, -jnp.inf),
        st=st, ref_keys=None, ref_cf=None, ref_st=None)


def _synthetic_plan_2d(L: int, deg: int, rng, dtype=jnp.float64):
    """Full uniform quadtree of depth g with L = 4^g leaves: descent arrays
    for the XLA backend plus the flat leaf tables in the plan's Morton
    layout for both Pallas paths."""
    from repro.engine.plan import big_sentinel
    from repro.kernels.locate import dyadic_cuts, leaf_morton_codes

    g = int(round(np.log(L) / np.log(4)))
    assert 4 ** g == L, f"L must be a power of 4, got {L}"
    children, bounds, leaf_of, leaf_nodes = [], [], [], []

    def build(x0, x1, y0, y1, d):
        node = len(children)
        children.append([-1, -1, -1, -1])
        bounds.append((x0, x1, y0, y1))
        leaf_of.append(-1)
        if d == g:
            leaf_of[node] = len(leaf_nodes)
            leaf_nodes.append(node)
            return node
        xm, ym = 0.5 * (x0 + x1), 0.5 * (y0 + y1)
        children[node][0] = build(x0, xm, y0, ym, d + 1)
        children[node][1] = build(xm, x1, y0, ym, d + 1)
        children[node][2] = build(x0, xm, ym, y1, d + 1)
        children[node][3] = build(xm, x1, ym, y1, d + 1)
        return node

    build(0.0, 100.0, 0.0, 100.0, 0)
    k = (deg + 1) * (deg + 1)
    coeffs_slot = rng.normal(0, 1, (L, k))
    bounds = np.asarray(bounds)
    lb = bounds[np.asarray(leaf_nodes)]
    xc = dyadic_cuts(0.0, 100.0, g)
    z = leaf_morton_codes(lb, xc, xc, g)
    order = np.argsort(z)
    lbz = lb[order]
    big = big_sentinel(dtype)
    mx1 = np.where(lbz[:, 1] >= 100.0, big, lbz[:, 1])
    my1 = np.where(lbz[:, 3] >= 100.0, big, lbz[:, 3])
    to = lambda a: jnp.asarray(a, dtype)
    return dict(mx0=to(lbz[:, 0]), mx1=to(mx1), my0=to(lbz[:, 2]),
                my1=to(my1), bounds=to(lbz), coeffs=to(coeffs_slot[order]),
                xcuts=to(xc), ycuts=to(xc),
                leaf_z=jnp.asarray(z[order], jnp.int32), depth=g,
                children=jnp.asarray(np.asarray(children, np.int32)),
                leaf_of=jnp.asarray(np.asarray(leaf_of, np.int32)),
                node_bounds=to(bounds),
                leaf_nodes=jnp.asarray(np.asarray(leaf_nodes, np.int32)),
                coeffs_slot=to(coeffs_slot))


def _synthetic_indexplan2d(tb, agg: str, deg: int, L: int):
    """Wrap the synthetic uniform-quadtree dict as a real IndexPlan2D so the
    engine's 2-D measure executors (execute_sum2d / execute_extremum2d)
    can run against it (Q_abs only — no refinement arrays)."""
    from repro.engine.plan import IndexPlan2D

    return IndexPlan2D(
        deg=deg, delta=1.0, n=L, n_leaves=L, max_depth=tb["depth"],
        bh=min(512, L), root=(0.0, 100.0, 0.0, 100.0),
        children=tb["children"], leaf_of=tb["leaf_of"],
        bounds=tb["node_bounds"], leaf_nodes=tb["leaf_nodes"],
        qt_coeffs=tb["coeffs_slot"],
        leaf_mx0=tb["mx0"], leaf_mx1=tb["mx1"], leaf_my0=tb["my0"],
        leaf_my1=tb["my1"], leaf_bounds=tb["bounds"],
        leaf_coeffs=tb["coeffs"], leaf_z=tb["leaf_z"], xcuts=tb["xcuts"],
        ycuts=tb["ycuts"], ref_xs=None, ref_ys_levels=None, agg=agg)


def _qt4(tb, lx, ux, ly, uy):
    """4-corner inclusion-exclusion through the quadtree descent (the XLA
    backend's op sequence) over the synthetic uniform tree."""
    from repro.core.index2d import quadtree_eval_cf

    ev = lambda u, v: quadtree_eval_cf(
        tb["children"], tb["leaf_of"], tb["node_bounds"], tb["coeffs_slot"],
        tb["leaf_nodes"], tb["depth"], 2, u, v)
    return ev(ux, uy) - ev(lx, uy) - ev(ux, ly) + ev(lx, ly)


def run_hsweep(hs=(512, 2048, 8192, 32768), hs2=(1024, 4096, 16384),
               nqh=512, record=None):
    """Locate->gather vs one-hot scan vs XLA as the table grows: the
    log-vs-linear crossover (DESIGN.md §10).  Synthetic tables, raw
    kernel/primitive timings (no Q_rel refinement)."""
    from repro.core.poly import eval_segments
    from repro.core.queries import max_eval_segments
    from repro.kernels.leaf_eval2d import (corner_count2d_gather_pallas,
                                           corner_count2d_pallas)
    from repro.kernels.range_max import (range_max_gather_pallas,
                                         range_max_pallas)
    from repro.kernels.range_sum import (range_sum_gather_pallas,
                                         range_sum_pallas)

    rows = []
    rng = np.random.default_rng(0x10C)

    def rec(name, t, derived=""):
        rows.append(row(name, t / nqh * 1e6, derived))
        if record is not None:
            record.append({"name": name, "us_per_query": t / nqh * 1e6,
                           "derived": derived})

    for H in hs:
        plan = _synthetic_plan_1d(H, "sum", 2, rng)
        lq = jnp.asarray(rng.uniform(0, 1000, nqh))
        uq = jnp.maximum(lq + 50.0, lq)
        runs = {
            "pallas": jax.jit(lambda l, u, p=plan: range_sum_gather_pallas(
                l, u, p.seg_lo, p.seg_hi, p.coeffs, bq=nqh)),
            "pallas_scan": jax.jit(lambda l, u, p=plan: range_sum_pallas(
                l, u, p.seg_lo, p.seg_next, p.seg_hi, p.coeffs,
                bq=nqh, bh=p.bh)),
            "xla": jax.jit(lambda l, u, p=plan: eval_segments(
                u, p.seg_lo, p.seg_hi, p.coeffs) - eval_segments(
                l, p.seg_lo, p.seg_hi, p.coeffs)),
        }
        for b, f in runs.items():
            t, _ = time_fn(f, lq, uq)
            rec(f"hsweep.sum.{b}.H{H}", t, f"Hpad={plan.seg_lo.shape[0]}")
        planm = _synthetic_plan_1d(H, "max", 3, rng)
        runs = {
            "pallas": jax.jit(lambda l, u, p=planm: range_max_gather_pallas(
                l, u, p.seg_lo, p.seg_hi, p.coeffs, p.st, bq=nqh)),
            "pallas_scan": jax.jit(lambda l, u, p=planm: range_max_pallas(
                l, u, p.seg_lo, p.seg_next, p.seg_hi, p.coeffs, p.seg_agg,
                bq=nqh, bh=p.bh)),
            "xla": jax.jit(lambda l, u, p=planm: max_eval_segments(
                p.seg_lo, p.seg_hi, p.coeffs, p.st, l, u)),
        }
        for b, f in runs.items():
            t, _ = time_fn(f, lq, uq)
            rec(f"hsweep.max.{b}.H{H}", t, f"Hpad={planm.seg_lo.shape[0]}")

    for L in hs2:
        tb = _synthetic_plan_2d(L, 2, rng)
        lx = jnp.asarray(rng.uniform(0, 100, nqh))
        ux = jnp.minimum(lx + 10.0, 100.0)
        ly = jnp.asarray(rng.uniform(0, 100, nqh))
        uy = jnp.minimum(ly + 10.0, 100.0)
        runs = {
            "pallas": jax.jit(lambda a, b, c, d: corner_count2d_gather_pallas(
                a, b, c, d, tb["xcuts"], tb["ycuts"], tb["leaf_z"],
                tb["bounds"], tb["coeffs"], deg=2, depth=tb["depth"],
                bq=nqh)),
            "pallas_scan": jax.jit(lambda a, b, c, d: corner_count2d_pallas(
                a, b, c, d, tb["mx0"], tb["mx1"], tb["my0"], tb["my1"],
                tb["bounds"], tb["coeffs"], deg=2, bq=nqh, bh=min(512, L))),
            "xla": jax.jit(lambda a, b, c, d: _qt4(tb, a, b, c, d)),
        }
        for b, f in runs.items():
            t, _ = time_fn(f, lx, ux, ly, uy)
            rec(f"hsweep.count2d.{b}.L{L}", t, f"Lpad={L}")

        # 2-D measure aggregates (DESIGN.md §12) through the engine
        # executors: SUM shares the 4-corner kernels, dominance MAX is the
        # single-corner eval path
        from repro.engine import execute_extremum2d, execute_sum2d

        plan_s = _synthetic_indexplan2d(tb, "sum2d", 2, L)
        plan_m = _synthetic_indexplan2d(tb, "max2d", 2, L)
        qu = jnp.asarray(rng.uniform(0, 100, nqh))
        qv = jnp.asarray(rng.uniform(0, 100, nqh))
        for b in ("pallas", "pallas_scan", "xla"):
            t, _ = time_fn(lambda a, c, d, e: execute_sum2d(
                plan_s, a, c, d, e, backend=b, bq=nqh), lx, ux, ly, uy)
            rec(f"hsweep.sum2d.{b}.L{L}", t, f"Lpad={L}")
            t, _ = time_fn(lambda a, c: execute_extremum2d(
                plan_m, a, c, backend=b, bq=nqh), qu, qv)
            rec(f"hsweep.max2d.{b}.L{L}", t, f"Lpad={L}")
    return rows


def run_shards(shard_h=4096, shard_nq=512, shard_s=(1, 2, 4, 8),
               out_path=None):
    """Sharded-plan sweep (`shard.{sum,max}.S{n}`): the shard_map executor
    against device-partitioned synthetic plans, S = 1 as the single-device
    reference point.  Needs >= max(shard_s) local devices (the CI job and
    `--shards` force host devices via XLA_FLAGS)."""
    from repro.engine.sharded import ShardedEngine, shard_plan

    if jax.device_count() < max(shard_s):
        raise RuntimeError(
            f"shard sweep needs {max(shard_s)} devices, have "
            f"{jax.device_count()}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={max(shard_s)}")
    rng = np.random.default_rng(0x5A)
    rows = []
    results = []
    lq = jnp.asarray(rng.uniform(0, 1000, shard_nq))
    uq = jnp.maximum(lq + 40.0, lq)

    def rec(name, t, derived=""):
        rows.append(row(name, t / shard_nq * 1e6, derived))
        results.append({"name": name, "us_per_query": t / shard_nq * 1e6,
                        "derived": derived})

    for agg, deg in (("sum", 2), ("max", 3)):
        plan = _synthetic_plan_1d(shard_h, agg, deg, rng)
        for s in shard_s:
            eng = ShardedEngine(s)
            splan = shard_plan(plan, s)   # partition outside the timed loop
            f = (eng.sum if agg == "sum" else eng.extremum)
            t, _ = time_fn(lambda l, u: f(splan, l, u), lq, uq)
            rec(f"shard.{agg}.S{s}", t,
                f"H={shard_h};Hs={splan.seg_lo.shape[1]}")

    _emit_engine_json(results, {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "shard_h": shard_h, "shard_nq": shard_nq, "shard_s": list(shard_s),
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }, out_path)
    return rows


def run_quantile(n=120_000, qn=512, deltas=(400.0, 100.0, 25.0),
                 out_path=None):
    """Certified quantile-inversion sweep (``quantile.{backend}.H{h}``):
    the branch-free locate->Newton executor over real fitted COUNT plans
    on TWEET latitudes, every engine backend, one plan per delta so H
    sweeps the segment count the inversion searches."""
    from repro.core import build_index_1d
    from repro.engine import BACKENDS, build_plan, execute_quantile

    rows = []
    results = []

    def rec(name, t, derived=""):
        rows.append(row(name, t / qn * 1e6, derived))
        results.append({"name": name, "us_per_query": t / qn * 1e6,
                        "derived": derived})

    keys, _ = dataset("tweet", n)
    rng = np.random.default_rng(0x0A7)
    qs = jnp.asarray(rng.uniform(0.0, 1.0, qn))
    for delta in deltas:
        plan = build_plan(build_index_1d(keys, None, "count", deg=2,
                                         delta=delta, keep_exact=True))
        for b in BACKENDS:
            f = functools.partial(execute_quantile, plan, backend=b, bq=qn)
            t, _ = time_fn(f, qs)
            rec(f"quantile.{b}.H{plan.h}", t,
                f"delta={delta:g};Hpad={plan.seg_lo.shape[0]}")

    _emit_engine_json(results, {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n": n, "nqh": qn,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }, out_path)
    return rows


def run(n=200_000, nq=4096, n2=40_000, nq2=1024, eps_rel=0.01,
        hs=(512, 2048, 8192, 32768), hs2=(1024, 4096, 16384), nqh=512,
        out_path=None):
    from repro.core import build_index_1d, build_index_2d
    from repro.data import make_queries_1d, make_queries_2d
    from repro.engine import BACKENDS, Engine, build_plan, build_plan_2d
    from repro.kernels import from_index, range_max, range_sum

    rows = []
    keys, _ = dataset("tweet", n)
    lq, uq = map(jnp.asarray, make_queries_1d(keys, nq))
    pf = build_index_1d(keys, None, "count", deg=2, delta=50.0)
    tbl = from_index(pf, dtype=jnp.float32)
    for backend in ("ref", "pallas"):
        f = functools.partial(range_sum, tbl, backend=backend)
        t, _ = time_fn(f, lq, uq)
        rows.append(row(f"kernels.range_sum.{backend}", t / nq * 1e6,
                        f"Hpad={tbl.seg_lo.shape[0]}"))
    tk, vals = dataset("hki", n)
    pfm = build_index_1d(tk, vals, "max", deg=3, delta=100.0)
    tblm = from_index(pfm, dtype=jnp.float32)
    l2, u2 = map(jnp.asarray, make_queries_1d(tk, nq))
    for backend in ("ref", "pallas"):
        f = functools.partial(range_max, tblm, backend=backend)
        t, _ = time_fn(f, l2, u2)
        rows.append(row(f"kernels.range_max.{backend}", t / nq * 1e6,
                        f"Hpad={tblm.seg_lo.shape[0]}"))

    # ---------------- engine backend sweep (fused Q_rel included) --------
    plan = build_plan(pf)
    planm = build_plan(pfm)
    px, py = dataset("osm", n2)
    pf2 = build_index_2d(px, py, deg=3, delta=50.0)
    plan2 = build_plan_2d(pf2)
    q2 = tuple(map(jnp.asarray, make_queries_2d(px, py, nq2)))
    engine_results = []

    def record(name, t, per, derived=""):
        rows.append(row(name, t / per * 1e6, derived))
        engine_results.append({"name": name, "us_per_query": t / per * 1e6,
                               "derived": derived})

    for b in BACKENDS:
        eng = Engine(backend=b)
        t, _ = time_fn(lambda l, u: eng.sum(plan, l, u), lq, uq)
        record(f"engine.sum.{b}.Qabs", t, nq, f"Hpad={plan.seg_lo.shape[0]}")
        t, _ = time_fn(lambda l, u: eng.sum(plan, l, u, eps_rel=eps_rel),
                       lq, uq)
        record(f"engine.sum.{b}.Qrel", t, nq)
        t, _ = time_fn(lambda l, u: eng.extremum(planm, l, u), l2, u2)
        record(f"engine.max.{b}.Qabs", t, nq,
               f"Hpad={planm.seg_lo.shape[0]}")
        t, _ = time_fn(lambda a, c, d, e: eng.count2d(plan2, a, c, d, e), *q2)
        record(f"engine.count2d.{b}.Qabs", t, nq2,
               f"Lpad={plan2.leaf_mx0.shape[0]}")

    # ---------------- H-sweep: the log-vs-linear crossover ----------------
    rows.extend(run_hsweep(hs=hs, hs2=hs2, nqh=nqh, record=engine_results))

    _emit_engine_json(engine_results, {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n": n, "nq": nq, "n2": n2, "nq2": nq2,
        "hs": list(hs), "hs2": list(hs2), "nqh": nqh,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }, out_path)

    # analytic roofline of the fused range_sum kernel on TPU v5e (f32)
    BQ, deg = 256, 2
    H = int(tbl.seg_lo.shape[0])
    flops = 2 * BQ * H * (deg + 3 + 2) + BQ * H * 2     # matmul + compares
    bytes_moved = (H * (deg + 3 + 3) * 4                # table once / block
                   + BQ * 4 * 3)
    ai = flops / bytes_moved
    t_compute = flops / PEAK_FLOPS
    t_mem = bytes_moved / HBM_BW
    rows.append(row("kernels.range_sum.roofline_model",
                    max(t_compute, t_mem) / BQ * 1e6,
                    f"AI={ai:.1f}flop/B;bound={'compute' if t_compute > t_mem else 'memory'}"))
    return rows


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="small shapes for the CI benchmark-smoke job "
                        "(meta matches the committed baseline record)")
    p.add_argument("--shards", action="store_true",
                   help="run the sharded-plan sweep (shard.{sum,max}.S{n}) "
                        "instead of the kernel/engine sweep; forces 8 host "
                        "devices if fewer are visible")
    p.add_argument("--quantile", action="store_true",
                   help="run the certified quantile-inversion sweep "
                        "(quantile.{backend}.H{h}) instead of the "
                        "kernel/engine sweep")
    p.add_argument("--out", default=None,
                   help="write the JSON record here instead of appending "
                        "to the committed BENCH_engine.json")
    args = p.parse_args()
    if args.shards:
        # must happen before jax initializes its backends (nothing above
        # touches devices at import time)
        import os
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=8").strip()
        run_shards(**SHARD_SWEEP, out_path=args.out)
    elif args.quantile:
        run_quantile(**(QUANTILE_TINY if args.tiny else QUANTILE_SWEEP),
                     out_path=args.out)
    elif args.tiny:
        run(**TINY, out_path=args.out)
    else:
        run(out_path=args.out)


if __name__ == "__main__":
    main()
