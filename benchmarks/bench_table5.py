"""Paper Table 5: response time of every method with deterministic error
guarantees, for COUNT (1 key), MAX (1 key), COUNT (2 keys), under Q_abs and
Q_rel.

PolyFit rows all route through the unified engine (``repro.engine.Engine``)
— one fused jitted executable per (aggregate, backend, batch-bucket), with
the Q_rel refinement inside the executable — sweeping the three backends
(XLA reference, Pallas interpret, jnp kernel-oracle).  Baselines: exact
(prefix-CF / sparse-table = the aR-tree stand-ins), RMI, FITing-tree, PGM.
Times are per-query (µs) over batches of 1000 — batched device evaluation is
the TPU-native execution model (DESIGN.md §6), and this container measures
on CPU; relative ordering is the reproducible claim.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import dataset, row, time_fn

_ENGINE_BACKENDS = ("xla", "ref", "pallas", "pallas_scan")
_BACKEND_TAG = {"xla": "polyfit", "ref": "polyfit_kernel_ref",
                "pallas": "polyfit_pallas_interp",
                "pallas_scan": "polyfit_pallas_onehot"}


def run(n1=200_000, n2=100_000, nq=1000, eps_abs=100.0, eps_rel=0.01):
    from repro.core import (FitingTree, PGMIndex, RMIIndex, build_index_1d,
                            build_index_2d)
    from repro.data import make_queries_1d, make_queries_2d
    from repro.engine import Engine, build_plan, build_plan_2d

    engines = {b: Engine(backend=b) for b in _ENGINE_BACKENDS}
    rows = []
    # ---------------- COUNT, 1 key (TWEET) ------------------------------
    keys, meas = dataset("tweet", n1)
    lq, uq = make_queries_1d(keys, nq)
    lqj, uqj = jnp.asarray(lq), jnp.asarray(uq)

    pf = build_index_1d(keys, None, "count", deg=2, delta=eps_abs / 2)
    plan = build_plan(pf)
    ft = FitingTree.build(keys, np.ones_like(keys), eps_abs / 2)
    pgm = PGMIndex.build(keys, np.ones_like(keys), eps_abs / 2)
    rmi = RMIIndex.build(keys, np.ones_like(keys))
    ex = pf.exact_sum

    for b in _ENGINE_BACKENDS:
        t, _ = time_fn(lambda l, u, e=engines[b]: e.sum(plan, l, u), lqj, uqj)
        rows.append(row(f"table5.count1.Qabs.{_BACKEND_TAG[b]}", t / nq * 1e6,
                        f"h={pf.h};size={plan.size_bytes()}B"))
    exact_fn = jax.jit(lambda l, u: ex.cf_at(u) - ex.cf_at(l))
    t, _ = time_fn(exact_fn, lqj, uqj)
    rows.append(row("table5.count1.Qabs.exact_prefix(aR)", t / nq * 1e6, ""))
    for nm, idx in (("fiting", ft), ("pgm", pgm)):
        f = jax.jit(lambda l, u, i=idx: i.query(l, u).answer)
        t, _ = time_fn(f, lqj, uqj)
        rows.append(row(f"table5.count1.Qabs.{nm}", t / nq * 1e6,
                        f"size={idx.size_bytes()}B"))
    f = jax.jit(lambda l, u: rmi.query(l, u).answer)
    t, _ = time_fn(f, lqj, uqj)
    rows.append(row("table5.count1.Qabs.rmi", t / nq * 1e6,
                    f"size={rmi.size_bytes()}B"))
    # Q_rel variants (fused refinement path included)
    t, _ = time_fn(lambda l, u: engines["xla"].sum(plan, l, u,
                                                   eps_rel=eps_rel), lqj, uqj)
    rows.append(row("table5.count1.Qrel.polyfit", t / nq * 1e6, ""))
    for nm, idx in (("fiting", ft), ("pgm", pgm), ("rmi", rmi)):
        f = jax.jit(lambda l, u, i=idx: i.query(l, u, eps_rel=eps_rel).answer)
        t, _ = time_fn(f, lqj, uqj)
        rows.append(row(f"table5.count1.Qrel.{nm}", t / nq * 1e6, ""))

    # ---------------- MAX, 1 key (HKI) ----------------------------------
    tkeys, vals = dataset("hki", min(n1, 900_000) if n1 >= 900_000 else n1)
    lq2, uq2 = make_queries_1d(tkeys, nq)
    l2, u2 = jnp.asarray(lq2), jnp.asarray(uq2)
    pfm = build_index_1d(tkeys, vals, "max", deg=3, delta=eps_abs)
    planm = build_plan(pfm)
    exm = pfm.exact_max
    for b in _ENGINE_BACKENDS:
        t, _ = time_fn(lambda l, u, e=engines[b]: e.extremum(planm, l, u),
                       l2, u2)
        rows.append(row(f"table5.max1.Qabs.{_BACKEND_TAG[b]}", t / nq * 1e6,
                        f"h={pfm.h};size={planm.size_bytes()}B"))
    exf = jax.jit(exm.query)
    t, _ = time_fn(exf, l2, u2)
    rows.append(row("table5.max1.Qabs.exact_sparse_table(aR)", t / nq * 1e6, ""))
    t, _ = time_fn(lambda l, u: engines["xla"].extremum(planm, l, u,
                                                        eps_rel=eps_rel), l2, u2)
    rows.append(row("table5.max1.Qrel.polyfit", t / nq * 1e6, ""))

    # ---------------- COUNT, 2 keys (OSM) -------------------------------
    px, py = dataset("osm", n2)
    x0, x1, y0, y1 = make_queries_2d(px, py, nq)
    xs = tuple(map(jnp.asarray, (x0, x1, y0, y1)))
    pf2 = build_index_2d(px, py, deg=3, delta=200.0 / 4)
    plan2 = build_plan_2d(pf2)
    for b in _ENGINE_BACKENDS:
        t, _ = time_fn(lambda a, c, d, e, g=engines[b]:
                       g.count2d(plan2, a, c, d, e), *xs)
        rows.append(row(f"table5.count2.Qabs.{_BACKEND_TAG[b]}", t / nq * 1e6,
                        f"leaves={pf2.n_leaves};size={plan2.size_bytes()}B"))
    ex2 = pf2.exact
    exf2 = jax.jit(lambda a, b, c, d: (ex2.cf(b, d) - ex2.cf(a, d)
                                       - ex2.cf(b, c) + ex2.cf(a, c)))
    t, _ = time_fn(exf2, *xs)
    rows.append(row("table5.count2.Qabs.exact_mergesort_tree(aR)", t / nq * 1e6, ""))
    t, _ = time_fn(lambda a, b, c, d: engines["xla"].count2d(
        plan2, a, b, c, d, eps_rel=eps_rel), *xs)
    rows.append(row("table5.count2.Qrel.polyfit", t / nq * 1e6, ""))
    return rows


if __name__ == "__main__":
    run()
