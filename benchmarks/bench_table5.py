"""Paper Table 5: response time of every method with deterministic error
guarantees, for COUNT (1 key), MAX (1 key), COUNT (2 keys), under Q_abs and
Q_rel.

Methods: PolyFit (XLA 'ref' backend + Pallas interpret backend), exact
(prefix-CF / sparse-table = the aR-tree stand-ins), RMI, FITing-tree, PGM.
Times are per-query (µs) over batches of 1000 — batched device evaluation is
the TPU-native execution model (DESIGN.md §6), and this container measures
on CPU; relative ordering is the reproducible claim.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .common import dataset, row, time_fn


def run(n1=200_000, n2=100_000, nq=1000, eps_abs=100.0, eps_rel=0.01):
    from repro.core import (ExactMax, ExactSum, FitingTree, PGMIndex,
                            RMIIndex, build_index_1d, build_index_2d,
                            query_max, query_sum, query_count_2d)
    from repro.data import make_queries_1d, make_queries_2d
    from repro.kernels import from_index, range_max, range_sum

    rows = []
    # ---------------- COUNT, 1 key (TWEET) ------------------------------
    keys, meas = dataset("tweet", n1)
    lq, uq = make_queries_1d(keys, nq)
    lqj, uqj = jnp.asarray(lq), jnp.asarray(uq)

    pf = build_index_1d(keys, None, "count", deg=2, delta=eps_abs / 2)
    tbl = from_index(pf, dtype=jnp.float64)
    ft = FitingTree.build(keys, np.ones_like(keys), eps_abs / 2)
    pgm = PGMIndex.build(keys, np.ones_like(keys), eps_abs / 2)
    rmi = RMIIndex.build(keys, np.ones_like(keys))
    ex = pf.exact_sum

    qsum = jax.jit(lambda l, u: query_sum(pf, l, u).answer)
    t, _ = time_fn(qsum, lqj, uqj)
    rows.append(row("table5.count1.Qabs.polyfit", t / nq * 1e6,
                    f"h={pf.h};size={pf.size_bytes()}B"))
    t, _ = time_fn(functools.partial(range_sum, tbl, backend="ref"), lqj, uqj)
    rows.append(row("table5.count1.Qabs.polyfit_kernel_ref", t / nq * 1e6, ""))
    t, _ = time_fn(functools.partial(range_sum, tbl, backend="pallas"), lqj, uqj)
    rows.append(row("table5.count1.Qabs.polyfit_pallas_interp", t / nq * 1e6, ""))
    exact_fn = jax.jit(lambda l, u: ex.cf_at(u) - ex.cf_at(l))
    t, _ = time_fn(exact_fn, lqj, uqj)
    rows.append(row("table5.count1.Qabs.exact_prefix(aR)", t / nq * 1e6, ""))
    for nm, idx in (("fiting", ft), ("pgm", pgm)):
        f = jax.jit(lambda l, u, i=idx: i.query(l, u).answer)
        t, _ = time_fn(f, lqj, uqj)
        rows.append(row(f"table5.count1.Qabs.{nm}", t / nq * 1e6,
                        f"size={idx.size_bytes()}B"))
    f = jax.jit(lambda l, u: rmi.query(l, u).answer)
    t, _ = time_fn(f, lqj, uqj)
    rows.append(row("table5.count1.Qabs.rmi", t / nq * 1e6,
                    f"size={rmi.size_bytes()}B"))
    # Q_rel variants (refinement path included)
    qsum_r = jax.jit(lambda l, u: query_sum(pf, l, u, eps_rel=eps_rel).answer)
    t, _ = time_fn(qsum_r, lqj, uqj)
    rows.append(row("table5.count1.Qrel.polyfit", t / nq * 1e6, ""))
    for nm, idx in (("fiting", ft), ("pgm", pgm), ("rmi", rmi)):
        f = jax.jit(lambda l, u, i=idx: i.query(l, u, eps_rel=eps_rel).answer)
        t, _ = time_fn(f, lqj, uqj)
        rows.append(row(f"table5.count1.Qrel.{nm}", t / nq * 1e6, ""))

    # ---------------- MAX, 1 key (HKI) ----------------------------------
    tkeys, vals = dataset("hki", min(n1, 900_000) if n1 >= 900_000 else n1)
    lq2, uq2 = make_queries_1d(tkeys, nq)
    l2, u2 = jnp.asarray(lq2), jnp.asarray(uq2)
    pfm = build_index_1d(tkeys, vals, "max", deg=3, delta=eps_abs)
    tblm = from_index(pfm, dtype=jnp.float64)
    exm = pfm.exact_max
    qmax = jax.jit(lambda l, u: query_max(pfm, l, u).answer)
    t, _ = time_fn(qmax, l2, u2)
    rows.append(row("table5.max1.Qabs.polyfit", t / nq * 1e6,
                    f"h={pfm.h};size={pfm.size_bytes()}B"))
    t, _ = time_fn(functools.partial(range_max, tblm, backend="ref"), l2, u2)
    rows.append(row("table5.max1.Qabs.polyfit_kernel_ref", t / nq * 1e6, ""))
    t, _ = time_fn(functools.partial(range_max, tblm, backend="pallas"), l2, u2)
    rows.append(row("table5.max1.Qabs.polyfit_pallas_interp", t / nq * 1e6, ""))
    exf = jax.jit(exm.query)
    t, _ = time_fn(exf, l2, u2)
    rows.append(row("table5.max1.Qabs.exact_sparse_table(aR)", t / nq * 1e6, ""))
    qmax_r = jax.jit(lambda l, u: query_max(pfm, l, u, eps_rel=eps_rel).answer)
    t, _ = time_fn(qmax_r, l2, u2)
    rows.append(row("table5.max1.Qrel.polyfit", t / nq * 1e6, ""))

    # ---------------- COUNT, 2 keys (OSM) -------------------------------
    px, py = dataset("osm", n2)
    x0, x1, y0, y1 = make_queries_2d(px, py, nq)
    xs = tuple(map(jnp.asarray, (x0, x1, y0, y1)))
    pf2 = build_index_2d(px, py, deg=3, delta=200.0 / 4)
    q2 = jax.jit(lambda a, b, c, d: query_count_2d(pf2, a, b, c, d).answer)
    t, _ = time_fn(q2, *xs)
    rows.append(row("table5.count2.Qabs.polyfit", t / nq * 1e6,
                    f"leaves={pf2.n_leaves};size={pf2.size_bytes()}B"))
    ex2 = pf2.exact
    exf2 = jax.jit(lambda a, b, c, d: (ex2.cf(b, d) - ex2.cf(a, d)
                                       - ex2.cf(b, c) + ex2.cf(a, c)))
    t, _ = time_fn(exf2, *xs)
    rows.append(row("table5.count2.Qabs.exact_mergesort_tree(aR)", t / nq * 1e6, ""))
    q2r = jax.jit(lambda a, b, c, d: query_count_2d(pf2, a, b, c, d,
                                                    eps_rel=eps_rel).answer)
    t, _ = time_fn(q2r, *xs)
    rows.append(row("table5.count2.Qrel.polyfit", t / nq * 1e6, ""))
    return rows


if __name__ == "__main__":
    run()
