"""Paper Figs. 11, 14-19: degree tuning, eps_abs / eps_rel sensitivity,
selectivity, scalability with n, and the delta size/time trade-off."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import dataset, row, time_fn


def fig11_degree(n=200_000, nq=1000):
    from repro.core import build_index_1d, query_max, query_sum
    from repro.data import make_queries_1d

    rows = []
    keys, _ = dataset("tweet", n)
    lq, uq = map(jnp.asarray, make_queries_1d(keys, nq))
    for deg in (1, 2, 3, 4):
        idx = build_index_1d(keys, None, "count", deg=deg, delta=50.0)
        f = jax.jit(lambda l, u, i=idx: query_sum(i, l, u).answer)
        t, _ = time_fn(f, lq, uq)
        rows.append(row(f"fig11.count1.deg{deg}", t / nq * 1e6, f"h={idx.h}"))
    tk, vals = dataset("hki", n)
    l2, u2 = map(jnp.asarray, make_queries_1d(tk, nq))
    for deg in (1, 2, 3):
        idx = build_index_1d(tk, vals, "max", deg=deg, delta=100.0)
        f = jax.jit(lambda l, u, i=idx: query_max(i, l, u).answer)
        t, _ = time_fn(f, l2, u2)
        rows.append(row(f"fig11.max1.deg{deg}", t / nq * 1e6, f"h={idx.h}"))
    return rows


def fig14_15_sensitivity(n=200_000, nq=1000):
    from repro.core import FitingTree, build_index_1d, query_sum
    from repro.data import make_queries_1d

    rows = []
    keys, _ = dataset("tweet", n)
    lq, uq = map(jnp.asarray, make_queries_1d(keys, nq))
    for eps in (100.0, 200.0, 400.0, 1000.0, 2000.0):
        pf = build_index_1d(keys, None, "count", deg=2, delta=eps / 2)
        f = jax.jit(lambda l, u, i=pf: query_sum(i, l, u).answer)
        t, _ = time_fn(f, lq, uq)
        rows.append(row(f"fig14.count1.polyfit.eps{int(eps)}", t / nq * 1e6,
                        f"h={pf.h}"))
        ft = FitingTree.build(keys, np.ones_like(keys), eps / 2)
        f = jax.jit(lambda l, u, i=ft: i.query(l, u).answer)
        t, _ = time_fn(f, lq, uq)
        rows.append(row(f"fig14.count1.fiting.eps{int(eps)}", t / nq * 1e6,
                        f"h={ft.h}"))
    for eps_rel in (0.005, 0.01, 0.05, 0.1, 0.2):
        pf = build_index_1d(keys, None, "count", deg=2, delta=100.0)
        f = jax.jit(lambda l, u, i=pf: query_sum(i, l, u, eps_rel=eps_rel).answer)
        t, res = time_fn(f, lq, uq)
        rows.append(row(f"fig15.count1.polyfit.rel{eps_rel}", t / nq * 1e6, ""))
    return rows


def fig16_max_sensitivity(n=200_000, nq=1000):
    from repro.core import build_index_1d, query_max
    from repro.data import make_queries_1d

    rows = []
    tk, vals = dataset("hki", n)
    lq, uq = map(jnp.asarray, make_queries_1d(tk, nq))
    for eps in (50.0, 100.0, 200.0, 500.0):
        idx = build_index_1d(tk, vals, "max", deg=3, delta=eps)
        f = jax.jit(lambda l, u, i=idx: query_max(i, l, u).answer)
        t, _ = time_fn(f, lq, uq)
        rows.append(row(f"fig16.max1.polyfit.eps{int(eps)}", t / nq * 1e6,
                        f"h={idx.h}"))
    return rows


def fig17_selectivity(n=200_000, nq=1000):
    from repro.core import build_index_1d, query_sum
    from repro.data import make_queries_1d

    rows = []
    keys, _ = dataset("tweet", n)
    pf = build_index_1d(keys, None, "count", deg=2, delta=50.0)
    for sel in (0.001, 0.01, 0.1, 0.5):
        lq, uq = map(jnp.asarray, make_queries_1d(keys, nq, selectivity=sel))
        f = jax.jit(lambda l, u: query_sum(pf, l, u).answer)
        t, _ = time_fn(f, lq, uq)
        rows.append(row(f"fig17.count1.polyfit.sel{sel}", t / nq * 1e6, ""))
    return rows


def fig18_scalability(sizes=(100_000, 300_000), nq=1000):
    from repro.core import build_index_1d, query_sum
    from repro.data import make_queries_1d

    rows = []
    for n in sizes:
        keys, _ = dataset("tweet", n)
        pf = build_index_1d(keys, None, "count", deg=2, delta=50.0,
                            method="parallel")
        lq, uq = map(jnp.asarray, make_queries_1d(keys, nq))
        f = jax.jit(lambda l, u, i=pf: query_sum(i, l, u).answer)
        t, _ = time_fn(f, lq, uq)
        rows.append(row(f"fig18.count1.polyfit.n{n}", t / nq * 1e6,
                        f"h={pf.h};size={pf.size_bytes()}B"))
    return rows


def fig19_tradeoff(n=200_000, nq=1000, eps_rel=0.01):
    from repro.core import FitingTree, build_index_1d, query_sum
    from repro.data import make_queries_1d

    rows = []
    keys, _ = dataset("tweet", n)
    lq, uq = map(jnp.asarray, make_queries_1d(keys, nq))
    for delta in (25.0, 50.0, 100.0, 200.0, 500.0, 1000.0):
        pf = build_index_1d(keys, None, "count", deg=2, delta=delta)
        f = jax.jit(lambda l, u, i=pf: query_sum(i, l, u, eps_rel=eps_rel).answer)
        t, res = time_fn(f, lq, uq)
        rows.append(row(f"fig19.count1.polyfit.delta{int(delta)}",
                        t / nq * 1e6, f"size={pf.size_bytes()}B;h={pf.h}"))
        ft = FitingTree.build(keys, np.ones_like(keys), delta)
        f2 = jax.jit(lambda l, u, i=ft: i.query(l, u, eps_rel=eps_rel).answer)
        t2, _ = time_fn(f2, lq, uq)
        rows.append(row(f"fig19.count1.fiting.delta{int(delta)}",
                        t2 / nq * 1e6, f"size={ft.size_bytes()}B;h={ft.h}"))
    return rows


def run():
    out = []
    out += fig11_degree()
    out += fig14_15_sensitivity()
    out += fig16_max_sensitivity()
    out += fig17_selectivity()
    out += fig18_scalability()
    out += fig19_tradeoff()
    return out


if __name__ == "__main__":
    run()
