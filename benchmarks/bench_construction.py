"""Paper Fig. 12/13: construction time vs dataset size and vs degree, plus
the beyond-paper parallel (lockstep-chunked batched-Lawson) builder and its
device-round count (the TPU-relevant latency metric)."""
from __future__ import annotations

import time


from .common import dataset, row


def run(sizes=(50_000, 100_000, 200_000), degs=(1, 2, 3, 4), delta=100.0):
    from repro.core import build_index_1d

    rows = []
    for n in sizes:
        keys, meas = dataset("tweet", n)
        t0 = time.perf_counter()
        idx = build_index_1d(keys, None, "count", deg=2, delta=delta / 2)
        t1 = time.perf_counter()
        rows.append(row(f"fig12.construction.greedy.n{n}", (t1 - t0) * 1e6,
                        f"h={idx.h}"))
        t0 = time.perf_counter()
        idxp = build_index_1d(keys, None, "count", deg=2, delta=delta / 2,
                              method="parallel")
        t1 = time.perf_counter()
        rows.append(row(f"fig12.construction.parallel.n{n}", (t1 - t0) * 1e6,
                        f"h={idxp.h}"))
    n = sizes[0]
    keys, _ = dataset("tweet", n)
    for deg in degs:
        t0 = time.perf_counter()
        idx = build_index_1d(keys, None, "count", deg=deg, delta=delta / 2)
        t1 = time.perf_counter()
        rows.append(row(f"fig13.construction.deg{deg}.n{n}", (t1 - t0) * 1e6,
                        f"h={idx.h}"))
    return rows


if __name__ == "__main__":
    run()
