"""Train a reduced LM (any of the 10 assigned archs) on CPU with the full
production stack: sharded params, AdamW, checkpointing, deterministic data.

    PYTHONPATH=src python examples/train_tiny_lm.py --arch mamba2-130m --steps 50
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=50)
    args = ap.parse_args()
    train_main(["--arch", args.arch, "--smoke", "--steps", str(args.steps),
                "--ckpt-dir", "/tmp/tiny_lm_ckpt", "--ckpt-every", "10"])


if __name__ == "__main__":
    main()
