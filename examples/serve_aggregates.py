"""End-to-end serving driver (the paper's deployment scenario): an analytics
service answering batched approximate range-aggregate requests against
PolyFit indexes through the unified engine — per-request-type jitted
executables, backend selection (XLA reference vs Pallas kernels), fused
Q_rel refinement, and latency accounting.

    PYTHONPATH=src python examples/serve_aggregates.py --batches 200
    PYTHONPATH=src python examples/serve_aggregates.py --backend pallas
"""
import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.serve import AggregateService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--backend", choices=("xla", "pallas", "pallas_scan", "ref"),
                    default="xla")
    args = ap.parse_args()

    srv = AggregateService(backend=args.backend)
    rng = np.random.default_rng(0)
    stats = {k: [] for k in ("count", "max", "count2d")}
    refined = {k: 0 for k in stats}
    total = {k: 0 for k in stats}
    for b in range(args.batches):
        kind = ("count", "max", "count2d")[b % 3]
        n = args.batch_size
        if kind in ("count", "max"):
            lo, hi = srv.domains[kind]
            a = rng.uniform(lo, hi, n); c = rng.uniform(lo, hi, n)
            req = (jnp.asarray(np.minimum(a, c)), jnp.asarray(np.maximum(a, c)))
        else:
            x0, x1, y0, y1 = srv.domains[kind]
            ax = rng.uniform(x0, x1, n); bx = ax + rng.uniform(0.1, 5, n)
            ay = rng.uniform(y0, y1, n); by = ay + rng.uniform(0.1, 5, n)
            req = tuple(map(jnp.asarray, (ax, bx, ay, by)))
        t0 = time.perf_counter()
        res = srv.serve(kind, *req)
        dt = time.perf_counter() - t0
        stats[kind].append(dt)
        refined[kind] += int(np.asarray(res.refined).sum())
        total[kind] += n

    print(f"\n[server] served {args.batches} batches x {args.batch_size} "
          f"requests (backend={args.backend})")
    for k, ts in stats.items():
        if not ts:
            continue
        ts = np.array(ts[1:] or ts)  # drop compile batch
        print(f"  {k:8s}: p50 {np.median(ts)*1e3:7.2f} ms/batch "
              f"({np.median(ts)/args.batch_size*1e6:6.2f} us/query)  "
              f"refine-rate {refined[k]/max(total[k],1):.3f}")


if __name__ == "__main__":
    main()
