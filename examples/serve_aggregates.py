"""End-to-end serving driver (the paper's deployment scenario): an analytics
service answering batched approximate range-aggregate requests against one
PolyFit session — declarative TableSpecs with a shared ErrorBudget, grouped
QueryBatch dispatch, backend selection (XLA reference vs Pallas kernels),
fused Q_rel refinement, and latency accounting.

    PYTHONPATH=src python examples/serve_aggregates.py --batches 200
    PYTHONPATH=src python examples/serve_aggregates.py --backend pallas
    PYTHONPATH=src python examples/serve_aggregates.py --mixed
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import QueryBatch, QuerySpec
from repro.serve import AggregateService


def _random_request(srv, kind, n, rng):
    if kind in ("count", "max"):
        lo, hi = srv.domains[kind]
        a = rng.uniform(lo, hi, n); c = rng.uniform(lo, hi, n)
        return (jnp.asarray(np.minimum(a, c)), jnp.asarray(np.maximum(a, c)))
    if kind == "max2d":   # dominance corners (DESIGN.md §12)
        x1, y1 = srv.domains[kind]
        return (jnp.asarray(rng.uniform(x1 - 40, x1, n)),
                jnp.asarray(rng.uniform(y1 - 40, y1, n)))
    x0, x1, y0, y1 = srv.domains[kind]
    ax = rng.uniform(x0, x1, n); bx = ax + rng.uniform(0.1, 5, n)
    ay = rng.uniform(y0, y1, n); by = ay + rng.uniform(0.1, 5, n)
    return tuple(map(jnp.asarray, (ax, bx, ay, by)))


def run_mixed(srv, batches, batch_size, rng):
    """The declarative path: one QueryBatch interleaving all three
    aggregate kinds per iteration, answered in request order."""
    sub = max(batch_size // 4, 1)
    times = []
    for _ in range(batches):
        batch = QueryBatch.of(
            QuerySpec("count", _random_request(srv, "count", sub, rng)),
            QuerySpec("sum2d", _random_request(srv, "sum2d", sub, rng)),
            QuerySpec("max", _random_request(srv, "max", sub, rng)),
            QuerySpec("max2d", _random_request(srv, "max2d", sub, rng)))
        t0 = time.perf_counter()
        results = srv.session.query(batch)
        jax.block_until_ready([r.answer for r in results])
        times.append(time.perf_counter() - t0)
    ts = np.array(times[1:] or times)
    print(f"  mixed   : p50 {np.median(ts)*1e3:7.2f} ms/batch "
          f"({np.median(ts)/(4*sub)*1e6:6.2f} us/query, "
          f"4 specs x {sub} queries)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--backend", choices=("xla", "pallas", "pallas_scan", "ref"),
                    default="xla")
    ap.add_argument("--mixed", action="store_true",
                    help="also time mixed-aggregate QueryBatch dispatch")
    args = ap.parse_args()

    srv = AggregateService(backend=args.backend)
    rng = np.random.default_rng(0)
    stats = {k: [] for k in ("count", "max", "count2d", "sum2d", "max2d")}
    refined = {k: 0 for k in stats}
    total = {k: 0 for k in stats}
    for b in range(args.batches):
        kind = ("count", "max", "count2d", "sum2d", "max2d")[b % 5]
        req = _random_request(srv, kind, args.batch_size, rng)
        t0 = time.perf_counter()
        res = srv.serve(kind, *req)
        dt = time.perf_counter() - t0
        stats[kind].append(dt)
        refined[kind] += int(np.asarray(res.refined).sum())
        total[kind] += args.batch_size

    print(f"\n[server] served {args.batches} batches x {args.batch_size} "
          f"requests (backend={args.backend})")
    for k, ts in stats.items():
        if not ts:
            continue
        ts = np.array(ts[1:] or ts)  # drop compile batch
        print(f"  {k:8s}: p50 {np.median(ts)*1e3:7.2f} ms/batch "
              f"({np.median(ts)/args.batch_size*1e6:6.2f} us/query)  "
              f"refine-rate {refined[k]/max(total[k],1):.3f}")
    if args.mixed:
        run_mixed(srv, max(args.batches // 3, 2), args.batch_size, rng)


if __name__ == "__main__":
    main()
