"""End-to-end serving driver (the paper's deployment scenario): an analytics
service answering batched approximate range-aggregate requests against
PolyFit indexes, with per-request-type guarantee handling, refinement
routing, and latency accounting.

    PYTHONPATH=src python examples/serve_aggregates.py --batches 200
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_index_1d, build_index_2d, query_count_2d, \
    query_max, query_sum
from repro.data import hki_series, osm_points, tweet_latitudes


class AggregateServer:
    """Holds one index per (dataset, aggregate); serves batched requests."""

    def __init__(self, eps_abs=100.0, eps_rel=0.01):
        self.eps_rel = eps_rel
        print("[server] building indexes ...")
        t0 = time.time()
        lat = tweet_latitudes(150_000)
        self.count_idx = build_index_1d(lat, None, "count", deg=2,
                                        delta=eps_abs / 2)
        self.count_domain = (lat.min(), lat.max())
        ts, vals = hki_series(150_000)
        self.max_idx = build_index_1d(ts, vals, "max", deg=3, delta=eps_abs)
        self.max_domain = (ts.min(), ts.max())
        px, py = osm_points(60_000)
        self.idx2d = build_index_2d(px, py, deg=3, delta=eps_abs / 4)
        self.dom2d = (px.min(), px.max(), py.min(), py.max())
        print(f"[server] ready in {time.time() - t0:.1f}s — sizes: "
              f"count={self.count_idx.size_bytes()}B "
              f"max={self.max_idx.size_bytes()}B "
              f"2d={self.idx2d.size_bytes()}B")
        # compile the three request kernels once
        self._count = jax.jit(lambda l, u: query_sum(
            self.count_idx, l, u, eps_rel=self.eps_rel))
        self._max = jax.jit(lambda l, u: query_max(
            self.max_idx, l, u, eps_rel=self.eps_rel))
        self._count2d = jax.jit(lambda a, b, c, d: query_count_2d(
            self.idx2d, a, b, c, d, eps_rel=self.eps_rel))

    def serve(self, kind, *args):
        fn = {"count": self._count, "max": self._max,
              "count2d": self._count2d}[kind]
        res = fn(*args)
        jax.block_until_ready(res.answer)
        return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=1024)
    args = ap.parse_args()

    srv = AggregateServer()
    rng = np.random.default_rng(0)
    lat = [], []
    stats = {k: [] for k in ("count", "max", "count2d")}
    refined = {k: 0 for k in stats}
    total = {k: 0 for k in stats}
    for b in range(args.batches):
        kind = ("count", "max", "count2d")[b % 3]
        n = args.batch_size
        if kind == "count":
            lo, hi = srv.count_domain
            a = rng.uniform(lo, hi, n); c = rng.uniform(lo, hi, n)
            req = (jnp.asarray(np.minimum(a, c)), jnp.asarray(np.maximum(a, c)))
        elif kind == "max":
            lo, hi = srv.max_domain
            a = rng.uniform(lo, hi, n); c = rng.uniform(lo, hi, n)
            req = (jnp.asarray(np.minimum(a, c)), jnp.asarray(np.maximum(a, c)))
        else:
            x0, x1, y0, y1 = srv.dom2d
            ax = rng.uniform(x0, x1, n); bx = ax + rng.uniform(0.1, 5, n)
            ay = rng.uniform(y0, y1, n); by = ay + rng.uniform(0.1, 5, n)
            req = tuple(map(jnp.asarray, (ax, bx, ay, by)))
        t0 = time.perf_counter()
        res = srv.serve(kind, *req)
        dt = time.perf_counter() - t0
        stats[kind].append(dt)
        refined[kind] += int(np.asarray(res.refined).sum())
        total[kind] += n

    print(f"\n[server] served {args.batches} batches x {args.batch_size} requests")
    for k, ts in stats.items():
        if not ts:
            continue
        ts = np.array(ts[1:] or ts)  # drop compile batch
        print(f"  {k:8s}: p50 {np.median(ts)*1e3:7.2f} ms/batch "
              f"({np.median(ts)/args.batch_size*1e6:6.2f} us/query)  "
              f"refine-rate {refined[k]/max(total[k],1):.3f}")


if __name__ == "__main__":
    main()
