"""Quickstart: fit a PolyFit session, answer approximate range aggregates
with deterministic guarantees through the declarative API, compare against
exact.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.api import ErrorBudget, PolyFit, QueryBatch, QuerySpec, TableSpec
from repro.data import hki_series, make_queries_1d, tweet_latitudes
from repro.engine.engine import truth_sum


def main():
    # one declarative fit: the ErrorBudget owns the eps_abs -> delta
    # derivations (Lemma 5.1: delta = eps_abs/2 for COUNT; 5.3: eps_abs
    # for MAX), so no hand-inlined /2 or /4 arithmetic anywhere
    lat = tweet_latitudes(200_000)
    t, v = hki_series(200_000)
    eps_abs = 100.0
    session = PolyFit.fit(
        {"lat": lat, "hki": (t, v)},
        {"lat": TableSpec("count", ErrorBudget(abs=eps_abs)),
         "hki": TableSpec("max", ErrorBudget(abs=50.0, rel=0.01))})

    # --- range COUNT over tweet-like latitudes (Q_abs guarantee) ----------
    plan = session.plan("lat")
    print(f"COUNT index: {plan.h} segments, {plan.size_bytes()} bytes "
          f"(vs {lat.nbytes} bytes of raw keys)")
    lqc, uqc = make_queries_1d(lat, 5)
    lqm, uqm = make_queries_1d(t, 5)

    # one mixed-aggregate batch; answers come back in request order
    res_count, res_max = session.query(QueryBatch.of(
        QuerySpec.range("lat", lqc, uqc),
        QuerySpec.range("hki", lqm, uqm)))

    truth = np.asarray(truth_sum(session.plan("lat"), jnp.asarray(lqc),
                                 jnp.asarray(uqc)))
    for l, u, a, tr in zip(lqc, uqc, np.asarray(res_count.answer), truth):
        print(f"  count in ({l:8.3f}, {u:8.3f}] ~ {a:10.1f}  exact {tr:8.0f}"
              f"  err {abs(a - tr):6.2f} <= {eps_abs}")

    # --- range MAX over a stock-index series (Q_rel + refinement) ---------
    planm = session.plan("hki")
    print(f"\nMAX index: {planm.h} segments, {planm.size_bytes()} bytes")
    truthm = np.asarray(session.query(
        QuerySpec.range("hki", lqm, uqm, rel=1e-12)).answer)
    for l, u, a, tr, rf in zip(lqm, uqm, np.asarray(res_max.answer), truthm,
                               np.asarray(res_max.refined)):
        print(f"  max in [{l:9.1f}, {u:9.1f}] ~ {a:10.1f}  exact {tr:10.1f}"
              f"  rel_err {abs(a - tr) / abs(tr):.4f}  refined={bool(rf)}")


if __name__ == "__main__":
    main()
