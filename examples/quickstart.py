"""Quickstart: build a PolyFit index, answer approximate range aggregates
with deterministic guarantees, compare against exact.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import build_index_1d, query_max, query_sum
from repro.data import hki_series, make_queries_1d, tweet_latitudes


def main():
    # --- range COUNT over tweet-like latitudes (Q_abs guarantee) ----------
    lat = tweet_latitudes(200_000)
    eps_abs = 100.0
    idx = build_index_1d(lat, None, "count", deg=2, delta=eps_abs / 2)
    print(f"COUNT index: {idx.h} segments, {idx.size_bytes()} bytes "
          f"(vs {lat.nbytes} bytes of raw keys)")
    lq, uq = make_queries_1d(lat, 5)
    res = query_sum(idx, lq, uq)
    truth = np.asarray(idx.exact_sum.cf_at(jnp.asarray(uq))
                       - idx.exact_sum.cf_at(jnp.asarray(lq)))
    for l, u, a, t in zip(lq, uq, np.asarray(res.answer), truth):
        print(f"  count in ({l:8.3f}, {u:8.3f}] ~ {a:10.1f}  exact {t:8.0f}  "
              f"err {abs(a - t):6.2f} <= {eps_abs}")

    # --- range MAX over a stock-index series (Q_rel + refinement) ---------
    t, v = hki_series(200_000)
    idxm = build_index_1d(t, v, "max", deg=3, delta=50.0)
    lq, uq = make_queries_1d(t, 5)
    resm = query_max(idxm, lq, uq, eps_rel=0.01)
    truthm = np.asarray(idxm.exact_max.query(jnp.asarray(lq), jnp.asarray(uq)))
    print(f"\nMAX index: {idxm.h} segments, {idxm.size_bytes()} bytes")
    for l, u, a, tr, rf in zip(lq, uq, np.asarray(resm.answer), truthm,
                               np.asarray(resm.refined)):
        print(f"  max in [{l:9.1f}, {u:9.1f}] ~ {a:10.1f}  exact {tr:10.1f}"
              f"  rel_err {abs(a - tr) / tr:.4f}  refined={bool(rf)}")


if __name__ == "__main__":
    main()
