"""Two-key spatial aggregates through the declarative API: quadtree
PolyFit over an OSM-like point cloud — rectangle COUNT and SUM with
4-corner inclusion-exclusion (Eq. 19 / DESIGN.md §12) and dominance MAX
at a corner.

    PYTHONPATH=src python examples/two_key_spatial.py
"""
import numpy as np

from repro.api import ErrorBudget, PolyFit, QuerySpec, TableSpec
from repro.data import make_queries_2d, osm_points


def main():
    px, py = osm_points(80_000)
    # synthetic per-node weights so the measure-carrying tables have
    # something to aggregate
    w = 50.0 + 20.0 * np.sin(px / 7.0) + 15.0 * np.cos(py / 11.0)
    eps_abs = 200.0
    # Lemma 6.3 (delta = eps_abs/4) lives inside the ErrorBudget; the SUM
    # budget is stated in measure units
    session = PolyFit.fit(
        {"osm": (px, py), "spend": (px, py, w), "peak": (px, py, w)},
        {"osm": TableSpec("count2d", ErrorBudget(abs=eps_abs)),
         "spend": TableSpec("sum2d",
                            ErrorBudget(abs=eps_abs * float(w.mean()))),
         "peak": TableSpec("max2d", ErrorBudget(abs=5.0))})
    plan = session.plan("osm")
    print(f"quadtree: {plan.n_leaves} leaves, {plan.size_bytes()} bytes, "
          f"max_depth={plan.max_depth} (n={len(px)})")

    x0, x1, y0, y1 = make_queries_2d(px, py, 8)
    res = session.query(QuerySpec.rect("osm", x0, x1, y0, y1))
    # rel=1e-12 forces the in-path exact refinement -> ground truth
    truth = np.asarray(session.query(
        QuerySpec.rect("osm", x0, x1, y0, y1, rel=1e-12)).answer)
    for i in range(len(x0)):
        a = float(np.asarray(res.answer)[i])
        print(f"  rect [{x0[i]:7.2f},{x1[i]:7.2f}]x[{y0[i]:7.2f},{y1[i]:7.2f}]"
              f" ~ {a:9.1f}  exact {truth[i]:7.0f}  err {abs(a - truth[i]):6.1f}"
              f" <= {eps_abs}")

    # rectangle SUM over the weighted points (same corners)
    sums = np.asarray(session.query(
        QuerySpec.rect("spend", x0, x1, y0, y1)).answer)
    exact = np.asarray(session.query(
        QuerySpec.rect("spend", x0, x1, y0, y1, rel=1e-12)).answer)
    print("sum2d:   " + "  ".join(
        f"{s_:10.0f}(err {abs(s_ - e_):7.1f})" for s_, e_ in
        zip(sums[:4], exact[:4])))

    # dominance MAX: the heaviest node south-west of each corner
    peak = np.asarray(session.query(
        QuerySpec.corner("peak", x1, y1)).answer)
    dom_truth = [w[(px <= a) & (py <= b)].max() for a, b in zip(x1, y1)]
    print("max2d:   " + "  ".join(
        f"{p_:6.2f}(exact {t_:6.2f})" for p_, t_ in
        zip(peak[:4], dom_truth[:4])))


if __name__ == "__main__":
    main()
