"""Two-key spatial COUNT (paper §6) through the declarative API: quadtree
PolyFit over an OSM-like point cloud; rectangle queries with 4-corner
inclusion-exclusion (Eq. 19).

    PYTHONPATH=src python examples/two_key_spatial.py
"""
import numpy as np

from repro.api import ErrorBudget, PolyFit, QuerySpec, TableSpec
from repro.data import make_queries_2d, osm_points


def main():
    px, py = osm_points(80_000)
    eps_abs = 200.0
    # Lemma 6.3 (delta = eps_abs/4) lives inside the ErrorBudget
    session = PolyFit.fit(
        {"osm": (px, py)},
        {"osm": TableSpec("count2d", ErrorBudget(abs=eps_abs))})
    plan = session.plan("osm")
    print(f"quadtree: {plan.n_leaves} leaves, {plan.size_bytes()} bytes, "
          f"max_depth={plan.max_depth} (n={len(px)})")

    x0, x1, y0, y1 = make_queries_2d(px, py, 8)
    res = session.query(QuerySpec.rect("osm", x0, x1, y0, y1))
    # rel=1e-12 forces the in-path exact refinement -> ground truth
    truth = np.asarray(session.query(
        QuerySpec.rect("osm", x0, x1, y0, y1, rel=1e-12)).answer)
    for i in range(len(x0)):
        a = float(np.asarray(res.answer)[i])
        print(f"  rect [{x0[i]:7.2f},{x1[i]:7.2f}]x[{y0[i]:7.2f},{y1[i]:7.2f}]"
              f" ~ {a:9.1f}  exact {truth[i]:7.0f}  err {abs(a - truth[i]):6.1f}"
              f" <= {eps_abs}")


if __name__ == "__main__":
    main()
