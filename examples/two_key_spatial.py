"""Two-key spatial COUNT (paper §6): quadtree PolyFit over an OSM-like point
cloud; rectangle queries with 4-corner inclusion-exclusion (Eq. 19).

    PYTHONPATH=src python examples/two_key_spatial.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import build_index_2d, query_count_2d
from repro.data import make_queries_2d, osm_points


def main():
    px, py = osm_points(80_000)
    eps_abs = 200.0
    idx = build_index_2d(px, py, deg=3, delta=eps_abs / 4)
    print(f"quadtree: {idx.n_leaves} leaves, {idx.size_bytes()} bytes, "
          f"max_depth={idx.max_depth} (n={len(px)})")
    x0, x1, y0, y1 = make_queries_2d(px, py, 8)
    res = query_count_2d(idx, x0, x1, y0, y1)
    t = idx.exact
    truth = np.asarray(
        t.cf(jnp.asarray(x1), jnp.asarray(y1)) - t.cf(jnp.asarray(x0), jnp.asarray(y1))
        - t.cf(jnp.asarray(x1), jnp.asarray(y0)) + t.cf(jnp.asarray(x0), jnp.asarray(y0)))
    for i in range(len(x0)):
        a = float(np.asarray(res.answer)[i])
        print(f"  rect [{x0[i]:7.2f},{x1[i]:7.2f}]x[{y0[i]:7.2f},{y1[i]:7.2f}]"
              f" ~ {a:9.1f}  exact {truth[i]:7.0f}  err {abs(a - truth[i]):6.1f}"
              f" <= {eps_abs}")


if __name__ == "__main__":
    main()
